//! Micro-benchmark harness (offline replacement for `criterion`), used by
//! every `cargo bench` target (`harness = false`). Warms up, then runs
//! timed batches until a wall-clock budget is hit, reporting min / median
//! / mean / p95 per-iteration times and derived throughput.
//!
//! Machine-readable output: a [`JsonSnapshot`] collects the same rows
//! and merges them into a shared perf-snapshot JSON file (the
//! `BENCH_9.json` artifact the CI bench step uploads), one `targets`
//! entry per bench binary, so `step_latency`, `host_gemm` and
//! `quant_formats` can all write into one file across separate
//! invocations.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::{Duration, Instant};

pub struct BenchOptions {
    pub warmup: Duration,
    pub measure: Duration,
    /// Minimum number of measured batches.
    pub min_batches: usize,
}

impl Default for BenchOptions {
    fn default() -> Self {
        BenchOptions {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            min_batches: 10,
        }
    }
}

impl BenchOptions {
    /// Apply the CLI overrides shared by every bench binary:
    /// `--warmup-ms`, `--measure-ms`, `--min-batches`. CI passes small
    /// budgets so the snapshot run stays fast; local runs keep the
    /// binary's defaults.
    pub fn with_args(mut self, args: &crate::util::cli::Args) -> BenchOptions {
        self.warmup = Duration::from_millis(args.u64("warmup-ms", self.warmup.as_millis() as u64));
        self.measure =
            Duration::from_millis(args.u64("measure-ms", self.measure.as_millis() as u64));
        self.min_batches = args.usize("min-batches", self.min_batches);
        self
    }
}

pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
    pub p95: Duration,
}

impl BenchResult {
    pub fn per_iter_ns(&self) -> f64 {
        self.median.as_nanos() as f64
    }

    /// items/second given items processed per iteration.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.median.as_secs_f64()
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark `f`, preventing the result from being optimized out via
/// `std::hint::black_box` at the call site (callers should black_box
/// inputs/outputs).
pub fn bench<F: FnMut()>(name: &str, opts: &BenchOptions, mut f: F) -> BenchResult {
    // Warmup and batch-size calibration: target ~1ms per batch.
    let warm_start = Instant::now();
    let mut calib_iters = 0u64;
    while warm_start.elapsed() < opts.warmup {
        f();
        calib_iters += 1;
    }
    let per_iter = opts.warmup.as_secs_f64() / calib_iters.max(1) as f64;
    let batch = ((1e-3 / per_iter).ceil() as u64).clamp(1, 1_000_000);

    let mut samples: Vec<Duration> = Vec::new();
    let mut total_iters = 0u64;
    let measure_start = Instant::now();
    while measure_start.elapsed() < opts.measure || samples.len() < opts.min_batches {
        let t = Instant::now();
        for _ in 0..batch {
            f();
        }
        samples.push(t.elapsed() / batch as u32);
        total_iters += batch;
        if samples.len() > 100_000 {
            break;
        }
    }
    samples.sort();
    let min = samples[0];
    let median = samples[samples.len() / 2];
    let p95 = samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)];
    let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
    let r = BenchResult { name: name.to_string(), iters: total_iters, min, median, mean, p95 };
    println!(
        "bench {:<48} median {:>10}  min {:>10}  mean {:>10}  p95 {:>10}  ({} iters)",
        r.name,
        fmt_dur(r.median),
        fmt_dur(r.min),
        fmt_dur(r.mean),
        fmt_dur(r.p95),
        r.iters
    );
    r
}

/// Print a throughput line in the same table format.
pub fn report_throughput(name: &str, result: &BenchResult, items_per_iter: f64, unit: &str) {
    println!(
        "bench {:<48} throughput {:>12.3e} {unit}/s",
        name,
        result.throughput(items_per_iter)
    );
}

// ---------------------------------------------------------------------------
// Machine-readable perf snapshot (`--json <path>`)
// ---------------------------------------------------------------------------

/// A finite JSON number (the harness never measures NaN/inf, but a
/// zero-duration median would derive an infinite throughput — clamp
/// rather than emit invalid JSON).
fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "0".to_string()
    }
}

/// Collects one bench binary's rows and merges them into a shared
/// snapshot file keyed by target name. The file is a plain JSON object
/// (`schema: mor-bench-v1`) with one `targets.<name>` array per bench
/// binary; re-running a binary replaces only its own entry, so the
/// five CI bench invocations compose one `BENCH_9.json`.
pub struct JsonSnapshot {
    target: String,
    path: PathBuf,
    rows: Vec<String>,
}

impl JsonSnapshot {
    pub fn new(target: &str, path: impl Into<PathBuf>) -> JsonSnapshot {
        JsonSnapshot { target: target.to_string(), path: path.into(), rows: Vec::new() }
    }

    /// `Some` when the binary was invoked with `--json <path>`.
    pub fn from_args(target: &str, args: &crate::util::cli::Args) -> Option<JsonSnapshot> {
        args.get("json").map(|p| JsonSnapshot::new(target, p))
    }

    /// Record one latency result (mirrors the stdout table row).
    pub fn record(&mut self, r: &BenchResult) {
        self.rows.push(format!(
            r#"{{"kind":"latency","name":"{}","median_ns":{},"mean_ns":{},"min_ns":{},"p95_ns":{},"iters":{}}}"#,
            r.name,
            json_num(r.median.as_nanos() as f64),
            json_num(r.mean.as_nanos() as f64),
            json_num(r.min.as_nanos() as f64),
            json_num(r.p95.as_nanos() as f64),
            r.iters,
        ));
    }

    /// Record one derived-throughput result.
    pub fn record_throughput(
        &mut self,
        name: &str,
        r: &BenchResult,
        items_per_iter: f64,
        unit: &str,
    ) {
        self.rows.push(format!(
            r#"{{"kind":"throughput","name":"{name}","items_per_s":{},"unit":"{unit}/s"}}"#,
            json_num(r.throughput(items_per_iter)),
        ));
    }

    /// Merge this target's rows into the snapshot file and write it.
    /// `threads` records the engine width **this target's** parallel
    /// rows ran at — stamped per `targets` entry, so invocations at
    /// different `MOR_THREADS` merging into one file stay correctly
    /// labeled.
    pub fn write(&self, threads: usize) -> std::io::Result<()> {
        let mut targets: BTreeMap<String, String> = std::fs::read_to_string(&self.path)
            .map(|s| parse_snapshot_targets(&s))
            .unwrap_or_default();
        targets.insert(
            self.target.clone(),
            format!("{{\"threads\":{threads},\"rows\":[{}]}}", self.rows.join(",")),
        );
        let body = format!(
            "{{\"schema\":\"mor-bench-v1\",\"targets\":{{{}}}}}\n",
            targets
                .iter()
                .map(|(k, v)| format!("\"{k}\":{v}"))
                .collect::<Vec<_>>()
                .join(",")
        );
        if let Some(dir) = self.path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(&self.path, body)?;
        println!("bench snapshot ({}) merged into {}", self.target, self.path.display());
        Ok(())
    }
}

/// Extract `targets.<name>` entries (each a `{"threads":N,"rows":[..]}`
/// object) from a snapshot this module wrote. Only has to understand
/// our own output — bench names contain no quotes, braces or
/// brackets — and degrades to "start fresh" on any surprise (snapshot
/// files are derived artifacts, never inputs).
fn parse_snapshot_targets(content: &str) -> BTreeMap<String, String> {
    let mut out = BTreeMap::new();
    let Some(pos) = content.find("\"targets\":{") else {
        return out;
    };
    let mut rest = &content[pos + "\"targets\":{".len()..];
    loop {
        rest = rest.trim_start();
        let Some(stripped) = rest.strip_prefix('"') else {
            return out; // '}' (done) or malformed: either way, stop.
        };
        let Some(name_end) = stripped.find('"') else {
            return out;
        };
        let name = &stripped[..name_end];
        let after_name = stripped[name_end + 1..].trim_start();
        let Some(value) = after_name.strip_prefix(':') else {
            return out;
        };
        let value = value.trim_start();
        let (open, close) = match value.chars().next() {
            Some('{') => ('{', '}'),
            Some('[') => ('[', ']'), // pre-per-target-threads files
            _ => return out,
        };
        let mut depth = 0usize;
        let mut end = None;
        for (i, c) in value.char_indices() {
            if c == open {
                depth += 1;
            } else if c == close {
                depth -= 1;
                if depth == 0 {
                    end = Some(i);
                    break;
                }
            }
        }
        let Some(end) = end else {
            return out;
        };
        out.insert(name.to_string(), value[..=end].to_string());
        rest = value[end + 1..].trim_start();
        rest = match rest.strip_prefix(',') {
            Some(r) => r,
            None => return out,
        };
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_orders_stats() {
        let opts = BenchOptions {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(20),
            min_batches: 3,
        };
        let mut acc = 0u64;
        let r = bench("noop", &opts, || {
            acc = std::hint::black_box(acc.wrapping_add(1));
        });
        assert!(r.iters > 0);
        assert!(r.min <= r.median && r.median <= r.p95);
    }

    #[test]
    fn snapshot_merges_targets_across_invocations() {
        let path = std::env::temp_dir()
            .join(format!("mor_bench_snap_{}.json", std::process::id()));
        std::fs::remove_file(&path).ok();
        let fake = BenchResult {
            name: "row_a".to_string(),
            iters: 10,
            min: Duration::from_nanos(100),
            median: Duration::from_nanos(150),
            mean: Duration::from_nanos(160),
            p95: Duration::from_nanos(200),
        };

        let mut first = JsonSnapshot::new("alpha", &path);
        first.record(&fake);
        first.record_throughput("row_a_tp", &fake, 1000.0, "elem");
        first.write(4).unwrap();

        let mut second = JsonSnapshot::new("beta", &path);
        second.record(&fake);
        second.write(4).unwrap();

        let content = std::fs::read_to_string(&path).unwrap();
        assert!(content.contains("\"schema\":\"mor-bench-v1\""));
        assert!(
            content.contains("\"alpha\":{\"threads\":4,\"rows\":["),
            "first target lost on merge: {content}"
        );
        assert!(content.contains("\"beta\":{\"threads\":4,\"rows\":["));
        assert!(content.contains("\"median_ns\":150"));
        assert!(content.contains("\"unit\":\"elem/s\""));

        // Re-running a target replaces its rows rather than
        // duplicating, and re-stamps only its own thread count.
        let mut rerun = JsonSnapshot::new("alpha", &path);
        rerun.record(&BenchResult { name: "row_b".to_string(), ..duplicate(&fake) });
        rerun.write(13).unwrap();
        let content = std::fs::read_to_string(&path).unwrap();
        assert!(content.contains("row_b"));
        assert!(!content.contains("row_a_tp"), "stale alpha rows survived: {content}");
        assert!(content.contains("\"alpha\":{\"threads\":13,"));
        assert!(content.contains("\"beta\":{\"threads\":4,"), "beta's thread stamp was relabeled");

        let targets = parse_snapshot_targets(&content);
        assert_eq!(targets.len(), 2);
        std::fs::remove_file(&path).ok();
    }

    fn duplicate(r: &BenchResult) -> BenchResult {
        BenchResult {
            name: r.name.clone(),
            iters: r.iters,
            min: r.min,
            median: r.median,
            mean: r.mean,
            p95: r.p95,
        }
    }

    #[test]
    fn snapshot_parser_rejects_garbage_gracefully() {
        assert!(parse_snapshot_targets("").is_empty());
        assert!(parse_snapshot_targets("{\"schema\":\"x\"}").is_empty());
        assert!(parse_snapshot_targets("{\"targets\":{\"a\":[1,2}").is_empty());
        let ok = parse_snapshot_targets(r#"{"targets":{"a":[{"n":1}],"b":[]}}"#);
        assert_eq!(ok.get("a").map(String::as_str), Some(r#"[{"n":1}]"#));
        assert_eq!(ok.get("b").map(String::as_str), Some("[]"));
    }
}
