//! Micro-benchmark harness (offline replacement for `criterion`), used by
//! every `cargo bench` target (`harness = false`). Warms up, then runs
//! timed batches until a wall-clock budget is hit, reporting min / median
//! / mean / p95 per-iteration times and derived throughput.

use std::time::{Duration, Instant};

pub struct BenchOptions {
    pub warmup: Duration,
    pub measure: Duration,
    /// Minimum number of measured batches.
    pub min_batches: usize,
}

impl Default for BenchOptions {
    fn default() -> Self {
        BenchOptions {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            min_batches: 10,
        }
    }
}

pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
    pub p95: Duration,
}

impl BenchResult {
    pub fn per_iter_ns(&self) -> f64 {
        self.median.as_nanos() as f64
    }

    /// items/second given items processed per iteration.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.median.as_secs_f64()
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark `f`, preventing the result from being optimized out via
/// `std::hint::black_box` at the call site (callers should black_box
/// inputs/outputs).
pub fn bench<F: FnMut()>(name: &str, opts: &BenchOptions, mut f: F) -> BenchResult {
    // Warmup and batch-size calibration: target ~1ms per batch.
    let warm_start = Instant::now();
    let mut calib_iters = 0u64;
    while warm_start.elapsed() < opts.warmup {
        f();
        calib_iters += 1;
    }
    let per_iter = opts.warmup.as_secs_f64() / calib_iters.max(1) as f64;
    let batch = ((1e-3 / per_iter).ceil() as u64).clamp(1, 1_000_000);

    let mut samples: Vec<Duration> = Vec::new();
    let mut total_iters = 0u64;
    let measure_start = Instant::now();
    while measure_start.elapsed() < opts.measure || samples.len() < opts.min_batches {
        let t = Instant::now();
        for _ in 0..batch {
            f();
        }
        samples.push(t.elapsed() / batch as u32);
        total_iters += batch;
        if samples.len() > 100_000 {
            break;
        }
    }
    samples.sort();
    let min = samples[0];
    let median = samples[samples.len() / 2];
    let p95 = samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)];
    let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
    let r = BenchResult { name: name.to_string(), iters: total_iters, min, median, mean, p95 };
    println!(
        "bench {:<48} median {:>10}  min {:>10}  mean {:>10}  p95 {:>10}  ({} iters)",
        r.name,
        fmt_dur(r.median),
        fmt_dur(r.min),
        fmt_dur(r.mean),
        fmt_dur(r.p95),
        r.iters
    );
    r
}

/// Print a throughput line in the same table format.
pub fn report_throughput(name: &str, result: &BenchResult, items_per_iter: f64, unit: &str) {
    println!(
        "bench {:<48} throughput {:>12.3e} {unit}/s",
        name,
        result.throughput(items_per_iter)
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_orders_stats() {
        let opts = BenchOptions {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(20),
            min_batches: 3,
        };
        let mut acc = 0u64;
        let r = bench("noop", &opts, || {
            acc = std::hint::black_box(acc.wrapping_add(1));
        });
        assert!(r.iters > 0);
        assert!(r.min <= r.median && r.median <= r.p95);
    }
}
