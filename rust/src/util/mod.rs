//! In-repo replacements for crates unavailable in the offline build
//! environment: a deterministic property-testing harness, a tiny CLI
//! argument parser, a micro-benchmark harness (used by `cargo bench`
//! targets with `harness = false`), a seeded RNG, the strict
//! environment-knob registry ([`env`]), and the parallel chunked
//! execution engine behind the quantization hot paths.

pub mod bench;
pub mod cli;
pub mod env;
pub mod par;
pub mod proptest;
pub mod rng;

pub use par::Parallelism;
