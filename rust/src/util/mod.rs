//! In-repo replacements for crates unavailable in the offline build
//! environment: a deterministic property-testing harness, a tiny CLI
//! argument parser, a micro-benchmark harness (used by `cargo bench`
//! targets with `harness = false`), and a seeded RNG.

pub mod bench;
pub mod cli;
pub mod proptest;
pub mod rng;
