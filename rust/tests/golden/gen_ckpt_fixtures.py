#!/usr/bin/env python3
"""Generate the byte-level checkpoint-container fixtures.

Writes `morckpt1_fixture.bin` / `morckpt2_fixture.bin`, the golden
images `rust/tests/checkpoint_roundtrip.rs` pins the on-disk encoding
against (see the format doc in `rust/src/coordinator/checkpoint.rs`).
Both encode the same logical checkpoint:

    step = 7
    tensors = [("w", shape [2, 2], f32 data [1.0, -2.0, 0.5, 3.0])]
    sections = [("note", b"hello")]        # v2 only; v1 drops sections

Everything is little-endian by construction (struct '<'), which is the
point: the Rust side must produce these exact bytes on any host.
"""

import pathlib
import struct

HERE = pathlib.Path(__file__).resolve().parent

STEP = 7
TENSORS = [("w", [2, 2], [1.0, -2.0, 0.5, 3.0])]
SECTIONS = [("note", b"hello")]


def name(s: str) -> bytes:
    b = s.encode()
    return struct.pack("<I", len(b)) + b


def tensor_list(tensors) -> bytes:
    out = struct.pack("<I", len(tensors))
    for tname, shape, data in tensors:
        assert len(data) == int.__mul__(*shape) if len(shape) == 2 else True
        out += name(tname)
        out += struct.pack("<I", len(shape))
        for d in shape:
            out += struct.pack("<Q", d)
        for v in data:
            out += struct.pack("<f", v)
    return out


def v1() -> bytes:
    return b"MORCKPT1" + struct.pack("<Q", STEP) + tensor_list(TENSORS)


def v2() -> bytes:
    out = b"MORCKPT2" + struct.pack("<Q", STEP)
    sections = [("params", tensor_list(TENSORS))] + [
        (n, payload) for n, payload in SECTIONS
    ]
    out += struct.pack("<I", len(sections))
    for n, payload in sections:
        out += name(n)
        out += struct.pack("<Q", len(payload))
        out += payload
    return out


def main():
    for fname, data in [("morckpt1_fixture.bin", v1()), ("morckpt2_fixture.bin", v2())]:
        path = HERE / fname
        path.write_bytes(data)
        print(f"wrote {path} ({len(data)} bytes)")
    # Self-check: the f32 payload really is LE (1.0f32 == 00 00 80 3f).
    assert b"\x00\x00\x80\x3f\x00\x00\x00\xc0" in v1()
    print("fixture self-check ok")


if __name__ == "__main__":
    main()
