#!/usr/bin/env python3
"""Regenerate the golden cross-validation vectors in this directory.

Each line is `<f32 bits as %08x> <expected encoding as hex>`; the
expected byte/word comes from `ml_dtypes` (the converter JAX uses), so
the Rust codecs in `rust/src/formats/` are pinned to the reference
implementation. Before writing, this script cross-checks a pure-Python
port of the Rust encoding algorithm against ml_dtypes on every emitted
value and aborts on any disagreement, so a stale ml_dtypes can never
produce a silently-wrong golden file.

Usage: python3 rust/tests/golden/gen_golden.py
"""

import os
import struct

import ml_dtypes
import numpy as np


def f32_bits(x):
    return struct.unpack("<I", struct.pack("<f", np.float32(x)))[0]


def bits_f32(b):
    return struct.unpack("<f", struct.pack("<I", b & 0xFFFFFFFF))[0]


def encode_fp8_py(x, exp_bits, man_bits, bias, has_inf):
    """Pure-Python port of rust/src/formats/fp8.rs encode_with
    (NanOnOverflow mode)."""
    bits = f32_bits(x)
    sign = ((bits >> 31) & 1) << 7
    exp_mask = (1 << exp_bits) - 1
    man_mask = (1 << man_bits) - 1
    xf = bits_f32(bits)

    if np.isnan(xf):
        if has_inf:
            return sign | (exp_mask << man_bits) | (1 << (man_bits - 1))
        return sign | (exp_mask << man_bits) | man_mask
    if np.isinf(xf):
        if has_inf:
            return sign | (exp_mask << man_bits)
        return sign | (exp_mask << man_bits) | man_mask  # NaN for e4m3

    abs_bits = bits & 0x7FFFFFFF
    if abs_bits == 0:
        return sign
    if abs_bits < 0x00800000:  # f32 subnormal: far below fp8 range
        return sign

    f32_exp = (abs_bits >> 23) - 127
    min_norm_exp = 1 - bias
    significand24 = (abs_bits & 0x007FFFFF) | 0x00800000
    if f32_exp >= min_norm_exp:
        drop = 23 - man_bits
    else:
        drop = 23 - man_bits + (min_norm_exp - f32_exp)
    if drop >= 33:
        return sign
    staged = significand24 << 10
    total_drop = drop + 10
    keep = staged >> total_drop
    round_bit = (staged >> (total_drop - 1)) & 1
    sticky = (staged & ((1 << (total_drop - 1)) - 1)) != 0
    rounded = keep + (1 if (round_bit and (sticky or (keep & 1) == 1)) else 0)

    if f32_exp >= min_norm_exp:
        exp = f32_exp
        sig = rounded
        if sig >= (1 << (man_bits + 1)):
            sig >>= 1
            exp += 1
        e_fp8 = exp + bias
        m_fp8 = sig & man_mask
    else:
        if rounded >= (1 << man_bits):
            e_fp8 = 1
            m_fp8 = rounded & man_mask
        else:
            e_fp8 = 0
            m_fp8 = rounded

    max_exp_field = exp_mask - 1 if has_inf else exp_mask
    overflowed = e_fp8 > max_exp_field or (
        not has_inf and e_fp8 == max_exp_field and m_fp8 == man_mask
    )
    if overflowed:
        if has_inf:
            return sign | (exp_mask << man_bits)  # Inf
        return sign | (exp_mask << man_bits) | man_mask  # NaN
    return sign | (e_fp8 << man_bits) | m_fp8


def sample_values(rng, n):
    """Random f32 values spanning normal, subnormal-range and overflow
    cases for fp8, plus deterministic edge values."""
    vals = []
    # Log-uniform magnitudes covering well below fp8 subnormals up to
    # well above both formats' max.
    mags = np.exp(rng.uniform(np.log(1e-9), np.log(1e6), n - 64)).astype(np.float32)
    signs = rng.choice([-1.0, 1.0], n - 64).astype(np.float32)
    vals.extend((mags * signs).tolist())
    edges = [
        0.0, -0.0, 1.0, -1.0, 448.0, -448.0, 464.0, 465.0, 57344.0, -57344.0,
        61440.0, 61441.0, 0.001953125, 0.0009765625, 1.52587890625e-5,
        6.103515625e-5, 7.62939453125e-6, 2.0**-17, 2.0**-20, 3.4e38,
        float("inf"), float("-inf"), 0.015625, 2.0**-6, 2.0**-14,
        1.0625, 1.1875, 1.125, 1.375, 240.0, 239.0, 241.0,
    ]
    vals.extend(np.float32(v) for v in edges)
    while len(vals) < n:
        vals.append(np.float32(rng.normal() * 10.0))
    return np.array(vals[:n], dtype=np.float32)


def gen_fp8(path, dtype, exp_bits, man_bits, bias, has_inf, n=8000, seed=20260731):
    rng = np.random.default_rng(seed)
    vals = sample_values(rng, n)
    expect = vals.astype(dtype).view(np.uint8)
    mismatches = 0
    lines = []
    for v, e in zip(vals, expect):
        b = f32_bits(v)
        ours = encode_fp8_py(v, exp_bits, man_bits, bias, has_inf)
        ours_d = np.array([ours], np.uint8).view(dtype)[0]
        e_d = np.array([e], np.uint8).view(dtype)[0]
        if ours != int(e) and not (np.isnan(float(ours_d)) and np.isnan(float(e_d))):
            mismatches += 1
            print(f"MISMATCH {path}: x={v} bits={b:08x} ours={ours:02x} ml_dtypes={int(e):02x}")
        lines.append(f"{b:08x} {int(e):02x}")
    assert mismatches == 0, f"{mismatches} mismatches vs ml_dtypes"
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"wrote {path}: {len(lines)} vectors")


def bf16_from_f32_py(x):
    """Port of rust/src/formats/bf16.rs Bf16::from_f32."""
    bits = f32_bits(x)
    if np.isnan(bits_f32(bits)):
        return ((bits >> 16) & 0xFFFF) | 0x0040
    round_bit = (bits >> 15) & 1
    sticky = bits & 0x7FFF
    hi = (bits >> 16) & 0xFFFF
    if round_bit == 1 and (sticky != 0 or (hi & 1) == 1):
        hi = (hi + 1) & 0xFFFF
    return hi


def gen_bf16(path, n=4000, seed=20260731):
    rng = np.random.default_rng(seed ^ 0xB16)
    mags = np.exp(rng.uniform(np.log(1e-38), np.log(3.4e38), n - 16)).astype(np.float32)
    signs = rng.choice([-1.0, 1.0], n - 16).astype(np.float32)
    vals = list((mags * signs).tolist())
    vals.extend(np.float32(v) for v in [
        0.0, -0.0, 1.0, -1.0, 1.0 + 2.0**-8, 1.0 + 3 * 2.0**-8, 3.3895314e38,
        3.4e38, float("inf"), float("-inf"), 2.0**-126, 1e-40, -1e-40,
        65504.0, 57344.0, 448.0,
    ])
    vals = np.array(vals[:n], dtype=np.float32)
    expect = vals.astype(ml_dtypes.bfloat16).view(np.uint16)
    mismatches = 0
    lines = []
    for v, e in zip(vals, expect):
        b = f32_bits(v)
        ours = bf16_from_f32_py(v)
        ours_f = np.array([ours], np.uint16).view(ml_dtypes.bfloat16)[0]
        e_f = np.array([e], np.uint16).view(ml_dtypes.bfloat16)[0]
        if ours != int(e) and not (np.isnan(float(ours_f)) and np.isnan(float(e_f))):
            mismatches += 1
            print(f"MISMATCH bf16: x={v} bits={b:08x} ours={ours:04x} ml_dtypes={int(e):04x}")
        lines.append(f"{b:08x} {int(e):04x}")
    assert mismatches == 0, f"{mismatches} bf16 mismatches vs ml_dtypes"
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"wrote {path}: {len(lines)} vectors")


def main():
    here = os.path.dirname(os.path.abspath(__file__))
    gen_fp8(os.path.join(here, "fp8_e4m3_golden.txt"),
            ml_dtypes.float8_e4m3fn, 4, 3, 7, False)
    gen_fp8(os.path.join(here, "fp8_e5m2_golden.txt"),
            ml_dtypes.float8_e5m2, 5, 2, 15, True)
    gen_bf16(os.path.join(here, "bf16_golden.txt"))


if __name__ == "__main__":
    main()
