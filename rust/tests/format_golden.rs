//! Golden-vector format tests: exhaustive 256-bit-pattern round trips
//! for both FP8 formats (encode/decode/quantize_dequantize including
//! saturation and NaN behavior), the bf16 golden table generated from
//! `ml_dtypes.bfloat16`, and E2M1/NVFP4 edge-case vectors. These run
//! with no artifacts — they pin the host codecs to the reference
//! converter bit-for-bit. Regenerate the tables with
//! `python3 tests/golden/gen_golden.py`.

use mor::formats::bf16::{self, Bf16};
use mor::formats::fp4::{self, E2M1_GRID, E2M1_MAX};
use mor::formats::fp8::{Fp8Format, Rounding, E4M3, E5M2};

/// Decode all 256 byte patterns, re-encode each decoded value, and
/// require the original byte back (modulo NaN canonicalization and the
/// sign of zero for redundant encodings — neither format has redundant
/// non-NaN encodings, so only NaN needs the special case).
fn exhaustive_roundtrip<F: Fp8Format>() {
    for b in 0u16..=255 {
        let b = b as u8;
        let v = F::decode(b);
        if v.is_nan() {
            assert!(F::decode(F::encode(v)).is_nan(), "{}: NaN byte {b:#04x}", F::NAME);
            continue;
        }
        if v.is_infinite() {
            // Only E5M2 has Inf encodings: NanOnOverflow preserves them,
            // Saturate clamps to ±MAX by design.
            assert_eq!(F::decode(F::encode_with(v, Rounding::NanOnOverflow)), v);
            assert_eq!(
                F::decode(F::encode_with(v, Rounding::Saturate)),
                v.signum() * F::MAX,
                "{}: Inf byte {b:#04x} must saturate to ±MAX",
                F::NAME
            );
            continue;
        }
        for mode in [Rounding::NanOnOverflow, Rounding::Saturate] {
            let e = F::encode_with(v, mode);
            assert_eq!(
                F::decode(e),
                v,
                "{}: byte {b:#04x} decodes to {v}, re-encodes to {e:#04x} ({mode:?})",
                F::NAME
            );
        }
        // quantize_dequantize must be exact on representable values.
        assert_eq!(F::quantize_dequantize(v, Rounding::Saturate), v, "{} qdq {b:#04x}", F::NAME);
    }
}

#[test]
fn e4m3_exhaustive_256_patterns() {
    exhaustive_roundtrip::<E4M3>();
}

#[test]
fn e5m2_exhaustive_256_patterns() {
    exhaustive_roundtrip::<E5M2>();
}

#[test]
fn e4m3_saturation_and_nan_behavior() {
    // Above max: NaN in ml_dtypes mode, clamp in saturate mode.
    for x in [449.0f32, 1e9, f32::INFINITY] {
        assert!(E4M3::quantize_dequantize(x, Rounding::NanOnOverflow).is_nan(), "x={x}");
        assert_eq!(E4M3::quantize_dequantize(x, Rounding::Saturate), 448.0, "x={x}");
        assert_eq!(E4M3::quantize_dequantize(-x, Rounding::Saturate), -448.0, "x={x}");
    }
    // NaN input encodes to the canonical NaN byte in both modes.
    for mode in [Rounding::NanOnOverflow, Rounding::Saturate] {
        assert!(E4M3::decode(E4M3::encode_with(f32::NAN, mode)).is_nan());
    }
    // 448 itself survives; the RNE tie at 464 rounds back down to 448.
    assert_eq!(E4M3::quantize_dequantize(448.0, Rounding::NanOnOverflow), 448.0);
    assert_eq!(E4M3::quantize_dequantize(464.0, Rounding::NanOnOverflow), 448.0);
}

#[test]
fn e5m2_saturation_inf_and_nan_behavior() {
    // E5M2 has a real Inf: overflow goes to Inf in ml_dtypes mode.
    assert!(E5M2::quantize_dequantize(1e6, Rounding::NanOnOverflow).is_infinite());
    assert_eq!(E5M2::quantize_dequantize(1e6, Rounding::Saturate), 57344.0);
    assert_eq!(E5M2::quantize_dequantize(-1e6, Rounding::Saturate), -57344.0);
    assert!(E5M2::quantize_dequantize(f32::INFINITY, Rounding::NanOnOverflow).is_infinite());
    assert_eq!(E5M2::quantize_dequantize(f32::INFINITY, Rounding::Saturate), 57344.0);
    assert!(E5M2::decode(E5M2::encode(f32::NAN)).is_nan());
    // Inf byte decodes to Inf with the right sign.
    assert_eq!(E5M2::decode(0x7C), f32::INFINITY);
    assert_eq!(E5M2::decode(0xFC), f32::NEG_INFINITY);
}

fn check_golden_fp8<F: Fp8Format>(path: &str) {
    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("{path}: {e} (regenerate with tests/golden/gen_golden.py)"));
    let mut checked = 0usize;
    for line in text.lines() {
        let (v, e) = line.split_once(' ').unwrap();
        let bits = u32::from_str_radix(v, 16).unwrap();
        let expect = u8::from_str_radix(e, 16).unwrap();
        let x = f32::from_bits(bits);
        let got = F::encode(x);
        let (gd, ed) = (F::decode(got), F::decode(expect));
        assert!(
            got == expect || (gd.is_nan() && ed.is_nan()),
            "{}: x={x} ({bits:08x}): ours {got:02x} ({gd}) vs ml_dtypes {expect:02x} ({ed})",
            F::NAME
        );
        checked += 1;
    }
    assert_eq!(checked, 8000, "{path} must hold 8000 vectors");
}

#[test]
fn fp8_e4m3_matches_ml_dtypes_golden() {
    check_golden_fp8::<E4M3>("tests/golden/fp8_e4m3_golden.txt");
}

#[test]
fn fp8_e5m2_matches_ml_dtypes_golden() {
    check_golden_fp8::<E5M2>("tests/golden/fp8_e5m2_golden.txt");
}

#[test]
fn bf16_matches_ml_dtypes_golden() {
    let path = "tests/golden/bf16_golden.txt";
    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("{path}: {e} (regenerate with tests/golden/gen_golden.py)"));
    let mut checked = 0usize;
    for line in text.lines() {
        let (v, e) = line.split_once(' ').unwrap();
        let bits = u32::from_str_radix(v, 16).unwrap();
        let expect = u16::from_str_radix(e, 16).unwrap();
        let x = f32::from_bits(bits);
        let got = Bf16::from_f32(x).0;
        let (gf, ef) = (Bf16(got).to_f32(), Bf16(expect).to_f32());
        assert!(
            got == expect || (gf.is_nan() && ef.is_nan()),
            "bf16: x={x} ({bits:08x}): ours {got:04x} vs ml_dtypes {expect:04x}"
        );
        checked += 1;
    }
    assert_eq!(checked, 4000, "{path} must hold 4000 vectors");
}

#[test]
fn bf16_edge_vectors() {
    // Exact values survive; max finite is the documented constant.
    for v in [0.0f32, -0.0, 1.0, -2.0, 448.0, 57344.0, bf16::MAX] {
        assert_eq!(bf16::quantize_dequantize(v), v);
    }
    // Overflow → Inf; f32 subnormals round to (signed) zero.
    assert!(bf16::quantize_dequantize(3.4e38).is_infinite());
    assert_eq!(bf16::quantize_dequantize(1e-40), 0.0);
    assert!(bf16::quantize_dequantize(-1e-40).is_sign_negative());
    // RNE tie: 1 + 2^-8 is halfway between 1.0 and 1 + 2^-7 → even.
    assert_eq!(bf16::quantize_dequantize(1.0 + f32::powi(2.0, -8)), 1.0);
}

#[test]
fn e2m1_edge_vectors() {
    // The full grid round-trips with both signs.
    for (code, g) in E2M1_GRID.iter().enumerate() {
        assert_eq!(fp4::e2m1_decode(code as u8), *g);
        assert_eq!(fp4::e2m1_quantize_dequantize(*g), *g);
        assert_eq!(fp4::e2m1_quantize_dequantize(-*g).abs(), *g);
    }
    // Saturation at ±6, nearest-grid rounding, ties to even code.
    assert_eq!(fp4::e2m1_quantize_dequantize(1e9), E2M1_MAX);
    assert_eq!(fp4::e2m1_quantize_dequantize(-1e9), -E2M1_MAX);
    assert_eq!(fp4::e2m1_quantize_dequantize(2.5), 2.0); // tie → even code 4
    assert_eq!(fp4::e2m1_quantize_dequantize(5.0), 4.0); // tie → even code 6
    assert_eq!(fp4::e2m1_quantize_dequantize(0.25), 0.0); // tie → code 0
    assert_eq!(fp4::e2m1_quantize_dequantize(0.26), 0.5);
    assert_eq!(fp4::e2m1_quantize_dequantize(3.4), 3.0);
    assert_eq!(fp4::e2m1_quantize_dequantize(3.6), 4.0);
}

#[test]
fn nvfp4_block_pipeline_edges() {
    // A 1x16 block with one dominant value: the scale maps it near
    // E2M1_MAX and small same-block values flush toward zero. (The
    // dominant value stays below E4M3_MAX * E2M1_MAX = 2688, the
    // format's representable ceiling.)
    let mut x = vec![0.01f32; 16];
    x[3] = 2000.0;
    let mut out = vec![0f32; 16];
    fp4::nvfp4_quantize_dequantize(&x, &mut out);
    assert!((out[3] - 2000.0).abs() / 2000.0 < 0.1, "dominant value kept: {}", out[3]);
    assert_eq!(out[0], 0.0, "tiny co-block values flush");
    // All-zero blocks pass through untouched.
    let z = vec![0f32; 32];
    let mut zo = vec![1f32; 32];
    fp4::nvfp4_quantize_dequantize(&z, &mut zo);
    assert_eq!(zo, z);
}
