//! The checkpoint container's correctness contract:
//!
//! * save → load round-trips are **bitwise** for both container
//!   versions (property-tested over adversarial tensor sets: empty
//!   tensors, 1-element tensors, 0-dim scalars, long names);
//! * every malformed-file class — bad magic, truncated payloads,
//!   oversized `name_len`/`ndims`/dims/section-count fields — returns
//!   an `anyhow` error: no panics, no allocations beyond the file's
//!   own size;
//! * the on-disk encoding is pinned byte-for-byte against committed
//!   golden fixtures (`tests/golden/morckpt*_fixture.bin`, generated
//!   by `tests/golden/gen_ckpt_fixtures.py`), so the format is
//!   endian-stable and cannot drift silently.

use mor::coordinator::checkpoint::{Checkpoint, MAX_NAME_LEN, MAX_NDIMS};
use mor::tensor::Tensor;
use mor::util::proptest::{prop, Gen};
use std::path::PathBuf;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("mor_ckptrt_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn golden(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden").join(name)
}

fn assert_tensors_bitwise_eq(a: &[(String, Tensor)], b: &[(String, Tensor)]) {
    assert_eq!(a.len(), b.len(), "tensor count");
    for ((na, ta), (nb, tb)) in a.iter().zip(b.iter()) {
        assert_eq!(na, nb, "tensor name");
        assert_eq!(ta.shape(), tb.shape(), "shape of {na}");
        for (i, (x, y)) in ta.data().iter().zip(tb.data().iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{na}[{i}]: {x} vs {y}");
        }
    }
}

/// A random tensor set covering the adversarial shapes: 0-dim scalars,
/// 1-element tensors, empty tensors (a zero dim), plus ordinary 1-D/2-D
/// tensors with denormal-to-huge magnitudes and signed zeros.
fn random_tensor_set(g: &mut Gen) -> Vec<(String, Tensor)> {
    let n = g.usize_in(0, 6);
    (0..n)
        .map(|i| {
            let shape: Vec<usize> = match g.usize_in(0, 5) {
                0 => vec![],                                   // 0-dim scalar
                1 => vec![1],                                  // 1 element
                2 => vec![g.usize_in(0, 3), 0],                // empty (zero dim)
                3 => vec![g.usize_in(1, 9)],                   // 1-D
                _ => vec![g.usize_in(1, 7), g.usize_in(1, 7)], // 2-D
            };
            let vol: usize = shape.iter().product();
            let data: Vec<f32> = (0..vol)
                .map(|_| match g.usize_in(0, 9) {
                    0 => 0.0,
                    1 => -0.0,
                    2 => f32::MIN_POSITIVE / 2.0, // subnormal
                    _ => g.f32_in(-1.0, 1.0) * g.f32_log_uniform(1e-30, 1e30),
                })
                .collect();
            let name = match i % 3 {
                0 => format!("t{i}"),
                1 => format!("decoder.layer.{i}.mlp.fc1.weight"),
                _ => "x".repeat(g.usize_in(1, 40)),
            };
            (name, Tensor::from_vec(&shape, data))
        })
        .collect()
}

#[test]
fn prop_v2_roundtrip_bitwise() {
    prop(120, |g: &mut Gen| {
        let mut ck = Checkpoint::new(g.next_u64(), random_tensor_set(g));
        for s in 0..g.usize_in(0, 3) {
            let payload: Vec<u8> = (0..g.usize_in(0, 64)).map(|_| g.u32() as u8).collect();
            ck.push_section(&format!("sect/{s}"), payload);
        }
        let back = Checkpoint::from_bytes(&ck.to_bytes_v2()).unwrap();
        assert_eq!(back.step, ck.step);
        assert_tensors_bitwise_eq(&back.tensors, &ck.tensors);
        assert_eq!(back.sections, ck.sections);
        true
    });
}

#[test]
fn prop_v1_roundtrip_bitwise() {
    prop(120, |g: &mut Gen| {
        let ck = Checkpoint::new(g.next_u64(), random_tensor_set(g));
        let back = Checkpoint::from_bytes(&ck.to_bytes_v1()).unwrap();
        assert_eq!(back.step, ck.step);
        assert_tensors_bitwise_eq(&back.tensors, &ck.tensors);
        assert!(back.sections.is_empty());
        true
    });
}

#[test]
fn v2_file_roundtrip_on_disk() {
    let dir = tmpdir("disk");
    let path = dir.join("a.ckpt");
    let mut ck = Checkpoint::new(
        42,
        vec![
            ("scalar".into(), Tensor::from_vec(&[], vec![3.25])),
            ("empty".into(), Tensor::zeros(&[2, 0])),
            ("w".into(), Tensor::normal(&[3, 5], 1.0, 7)),
        ],
    );
    ck.push_section("opaque", vec![0, 255, 7]);
    ck.save(&path).unwrap();
    let back = Checkpoint::load(&path).unwrap();
    assert_eq!(back, ck);
    assert_eq!(back.get("scalar").unwrap().data(), &[3.25]);
    assert_eq!(back.get("empty").unwrap().len(), 0);
    std::fs::remove_dir_all(dir).ok();
}

// ---------------------------------------------------------------------------
// Malformed-input classes: each must error, never panic or over-allocate
// ---------------------------------------------------------------------------

fn le32(v: u32) -> [u8; 4] {
    v.to_le_bytes()
}

fn le64(v: u64) -> [u8; 8] {
    v.to_le_bytes()
}

/// A minimal *valid* v1 image: step 1, one tensor "w" = [2] of zeros.
fn valid_v1() -> Vec<u8> {
    let mut b = Vec::new();
    b.extend_from_slice(b"MORCKPT1");
    b.extend_from_slice(&le64(1));
    b.extend_from_slice(&le32(1)); // ntensors
    b.extend_from_slice(&le32(1)); // name_len
    b.push(b'w');
    b.extend_from_slice(&le32(1)); // ndims
    b.extend_from_slice(&le64(2)); // dim = 2
    b.extend_from_slice(&[0u8; 8]); // 2 f32 zeros
    b
}

#[test]
fn malformed_bad_magic_errors() {
    assert!(Checkpoint::from_bytes(b"NOTACKPT").is_err());
    assert!(Checkpoint::from_bytes(b"MORCKPT9\x01\x00\x00\x00\x00\x00\x00\x00").is_err());
    assert!(Checkpoint::from_bytes(b"").is_err());
    assert!(Checkpoint::from_bytes(b"MOR").is_err()); // shorter than magic
}

#[test]
fn malformed_truncations_error() {
    let good = valid_v1();
    assert!(Checkpoint::from_bytes(&good).is_ok(), "baseline image must parse");
    // Every strict prefix is a truncation of some field and must error.
    for cut in 8..good.len() {
        assert!(
            Checkpoint::from_bytes(&good[..cut]).is_err(),
            "truncation at {cut} bytes parsed successfully"
        );
    }
}

#[test]
fn malformed_oversized_name_len_errors() {
    // name_len = u32::MAX: the cap (MAX_NAME_LEN) must reject it before
    // any allocation of that size is attempted.
    let mut b = Vec::new();
    b.extend_from_slice(b"MORCKPT1");
    b.extend_from_slice(&le64(1));
    b.extend_from_slice(&le32(1)); // ntensors
    b.extend_from_slice(&le32(u32::MAX)); // absurd name_len
    b.push(b'w');
    let err = Checkpoint::from_bytes(&b).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains(&MAX_NAME_LEN.to_string()) || msg.contains("truncated"), "{msg}");
}

#[test]
fn malformed_oversized_ndims_errors() {
    let mut b = Vec::new();
    b.extend_from_slice(b"MORCKPT1");
    b.extend_from_slice(&le64(1));
    b.extend_from_slice(&le32(1)); // ntensors
    b.extend_from_slice(&le32(1));
    b.push(b'w');
    b.extend_from_slice(&le32(1_000_000)); // ndims far past MAX_NDIMS
    let err = Checkpoint::from_bytes(&b).unwrap_err();
    assert!(format!("{err:#}").contains(&MAX_NDIMS.to_string()), "{err:#}");
}

#[test]
fn malformed_oversized_dims_error() {
    // Dims whose volume would dwarf the file: the data read must be
    // bounded by the remaining bytes, not the claimed volume.
    for dims in [[u64::MAX, 2], [1 << 40, 1 << 40], [1 << 20, 1 << 20]] {
        let mut b = Vec::new();
        b.extend_from_slice(b"MORCKPT1");
        b.extend_from_slice(&le64(1));
        b.extend_from_slice(&le32(1)); // ntensors
        b.extend_from_slice(&le32(1));
        b.push(b'w');
        b.extend_from_slice(&le32(2)); // ndims
        for d in dims {
            b.extend_from_slice(&le64(d));
        }
        b.extend_from_slice(&[0u8; 64]); // nowhere near vol * 4 bytes
        assert!(Checkpoint::from_bytes(&b).is_err(), "dims {dims:?} accepted");
    }
}

#[test]
fn malformed_tensor_count_errors() {
    // A tensor count the file cannot possibly hold.
    let mut b = Vec::new();
    b.extend_from_slice(b"MORCKPT1");
    b.extend_from_slice(&le64(1));
    b.extend_from_slice(&le32(u32::MAX));
    assert!(Checkpoint::from_bytes(&b).is_err());
}

#[test]
fn malformed_v2_sections_error() {
    // Section count past the cap.
    let mut b = Vec::new();
    b.extend_from_slice(b"MORCKPT2");
    b.extend_from_slice(&le64(1));
    b.extend_from_slice(&le32(100_000));
    assert!(Checkpoint::from_bytes(&b).is_err());

    // Section payload length pointing past the end of the file.
    let mut b = Vec::new();
    b.extend_from_slice(b"MORCKPT2");
    b.extend_from_slice(&le64(1));
    b.extend_from_slice(&le32(1));
    b.extend_from_slice(&le32(6));
    b.extend_from_slice(b"params");
    b.extend_from_slice(&le64(u64::MAX)); // absurd payload length
    assert!(Checkpoint::from_bytes(&b).is_err());

    // A v2 container without a params section is rejected.
    let mut b = Vec::new();
    b.extend_from_slice(b"MORCKPT2");
    b.extend_from_slice(&le64(1));
    b.extend_from_slice(&le32(1));
    b.extend_from_slice(&le32(4));
    b.extend_from_slice(b"note");
    b.extend_from_slice(&le64(0));
    assert!(Checkpoint::from_bytes(&b).is_err());
}

#[test]
fn malformed_duplicate_sections_error() {
    // Duplicate names would make section lookups ambiguous; the loader
    // rejects them rather than picking a winner.
    let empty_params: Vec<u8> = le32(0).to_vec(); // ntensors = 0
    let mut b = Vec::new();
    b.extend_from_slice(b"MORCKPT2");
    b.extend_from_slice(&le64(1));
    b.extend_from_slice(&le32(3));
    for (name, payload) in
        [("params", &empty_params), ("note", &vec![7u8]), ("note", &vec![8u8])]
    {
        b.extend_from_slice(&le32(name.len() as u32));
        b.extend_from_slice(name.as_bytes());
        b.extend_from_slice(&le64(payload.len() as u64));
        b.extend_from_slice(payload);
    }
    let err = Checkpoint::from_bytes(&b).unwrap_err();
    assert!(format!("{err:#}").contains("duplicate"), "{err:#}");
}

#[test]
fn malformed_trailing_garbage_errors() {
    let mut good = valid_v1();
    good.push(0xAA);
    assert!(Checkpoint::from_bytes(&good).is_err());
}

#[test]
fn malformed_non_utf8_name_errors() {
    let mut b = Vec::new();
    b.extend_from_slice(b"MORCKPT1");
    b.extend_from_slice(&le64(1));
    b.extend_from_slice(&le32(1)); // ntensors
    b.extend_from_slice(&le32(2)); // name_len
    b.extend_from_slice(&[0xFF, 0xFE]); // invalid utf8
    b.extend_from_slice(&le32(0)); // ndims = 0 (scalar)
    b.extend_from_slice(&[0u8; 4]);
    assert!(Checkpoint::from_bytes(&b).is_err());
}

// ---------------------------------------------------------------------------
// Byte-level golden fixtures: the encoding is pinned, endian-stably
// ---------------------------------------------------------------------------

/// The checkpoint value both fixtures encode (see
/// `tests/golden/gen_ckpt_fixtures.py`).
fn fixture_checkpoint() -> Checkpoint {
    let mut ck = Checkpoint::new(
        7,
        vec![("w".into(), Tensor::from_vec(&[2, 2], vec![1.0, -2.0, 0.5, 3.0]))],
    );
    ck.push_section("note", b"hello".to_vec());
    ck
}

#[test]
fn golden_fixture_v1_bytes_pinned() {
    let want = std::fs::read(golden("morckpt1_fixture.bin"))
        .expect("committed fixture tests/golden/morckpt1_fixture.bin");
    // Encoder reproduces the committed bytes exactly (v1 drops the
    // extra section by design)...
    assert_eq!(fixture_checkpoint().to_bytes_v1(), want, "v1 encoding drifted");
    // ...and the committed bytes decode to the expected value.
    let back = Checkpoint::from_bytes(&want).unwrap();
    assert_eq!(back.step, 7);
    assert_tensors_bitwise_eq(&back.tensors, &fixture_checkpoint().tensors);
}

// ---------------------------------------------------------------------------
// Option pinning: the decision policy is part of the resume contract
// ---------------------------------------------------------------------------

/// MORCKPT2 checkpoints pin the decision policy fingerprint
/// (`opt/policy`): resuming under a different policy changes every
/// quantization decision, so it must error loudly instead of silently
/// diverging from the bitwise resume ≡ continuous contract. Resuming
/// with the original policy spelled explicitly still works.
#[test]
fn resume_rejects_policy_mismatch() {
    use mor::coordinator::trainer::{Trainer, TrainerOptions};
    use mor::model::config::{ModelConfig, TrainConfig};
    use mor::mor::policy;
    use mor::runtime::Runtime;
    use mor::util::par::Parallelism;

    const ARTIFACT: &str = "train_mor_tensor_block";
    let rt = Runtime::host(ModelConfig::TINY);
    let trainer = Trainer::new(&rt, TrainConfig::config1(4));
    let base = tmpdir("policy_pin");
    let mk = |out: PathBuf, resume: Option<PathBuf>, spec: Option<&str>| {
        let mut o = TrainerOptions::new(ARTIFACT, 4, out);
        o.val_every = 2;
        o.ckpt_every = 2;
        o.quiet = true;
        o.resume = resume;
        o.policy = spec.map(|s| policy::parse_policy(Some(s)).unwrap().unwrap());
        o.parallelism = Some(Parallelism::serial());
        o
    };
    trainer.run(&mk(base.join("orig"), None, None)).unwrap();
    let ckpt = base.join("orig").join(format!("{ARTIFACT}.step2.ckpt"));
    assert!(ckpt.exists(), "checkpoint missing");

    // Different policy → hard error naming the flag.
    let err = trainer
        .run(&mk(base.join("bad"), Some(ckpt.clone()), Some("metric=0.03")))
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("--policy"), "error should name the mismatched flag: {msg}");

    // Original (default threshold) policy, spelled explicitly →
    // resumes fine and reproduces the continuous run bitwise.
    let cont = trainer.run(&mk(base.join("cont"), None, Some("threshold"))).unwrap();
    let res =
        trainer.run(&mk(base.join("res"), Some(ckpt), Some("threshold"))).unwrap();
    assert_eq!(cont.records.len(), res.records.len());
    for (a, b) in cont.records.iter().zip(res.records.iter()) {
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "step {}", a.step);
        assert_eq!(a.param_norm.to_bits(), b.param_norm.to_bits(), "step {}", a.step);
    }
    std::fs::remove_dir_all(base).ok();
}

#[test]
fn golden_fixture_v2_bytes_pinned() {
    let want = std::fs::read(golden("morckpt2_fixture.bin"))
        .expect("committed fixture tests/golden/morckpt2_fixture.bin");
    assert_eq!(fixture_checkpoint().to_bytes_v2(), want, "v2 encoding drifted");
    let back = Checkpoint::from_bytes(&want).unwrap();
    assert_eq!(back, fixture_checkpoint());
    // Spot-check the f32 payload bytes really are little-endian
    // to_le_bytes output: 1.0f32 == 3F80_0000.
    let pos = want
        .windows(4)
        .position(|w| w == [0x00, 0x00, 0x80, 0x3F])
        .expect("LE bytes of 1.0f32 present in fixture");
    // -2.0f32 == C000_0000 follows immediately.
    assert_eq!(&want[pos + 4..pos + 8], &[0x00, 0x00, 0x00, 0xC0]);
}
