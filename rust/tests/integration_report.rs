//! Smoke tests for the report harness on the tiny preset: a handful of
//! short runs proving that every table/figure code path executes and
//! produces the paper-shaped outputs.

use mor::model::config::ModelConfig;
use mor::report::{runs, ReportCtx};
use std::path::Path;

fn ctx(steps: u64, tag: &str) -> Option<ReportCtx> {
    let dir = Path::new("artifacts/tiny");
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: artifacts/tiny not built");
        return None;
    }
    let out = std::env::temp_dir().join(format!("mor_report_{tag}_{}", std::process::id()));
    let mut c = ReportCtx::new(dir, ModelConfig::TINY, steps, out).expect("ctx");
    c.quiet = true;
    Some(c)
}

#[test]
fn table1_prints() {
    let Some(c) = ctx(4, "t1") else { return };
    c.run_experiment("table1").unwrap();
}

#[test]
fn run_variant_caches() {
    let Some(c) = ctx(5, "cache") else { return };
    let r1 = runs::run_variant(&c, "block", "train_mor_tensor_block", 1, 0.045, false, false)
        .unwrap();
    assert_eq!(r1.records.len(), 5);
    assert!(r1.csv_path.exists());
    // Second call — even one demanding stats + suite — must hit the
    // in-memory memo (every executed run carries both).
    let t0 = std::time::Instant::now();
    let r2 =
        runs::run_variant(&c, "block", "train_mor_tensor_block", 1, 0.045, true, true).unwrap();
    assert!(t0.elapsed().as_millis() < 100, "expected memoized run");
    assert!(std::rc::Rc::ptr_eq(&r1, &r2));
    assert!(r2.stats.is_some() && !r2.suite_history.is_empty());
    std::fs::remove_dir_all(&c.out_dir).ok();
}

#[test]
fn fig10_shape_holds_directionally() {
    // The channel strategy must not fall back more than the per-tensor
    // strategy (paper Fig. 10's headline ordering), measured on a short
    // tiny-model run. Uses the stats-bearing path.
    let Some(c) = ctx(6, "fig10") else { return };
    let tensor = runs::run_variant(&c, "tensor", "train_mor_tensor_tensor", 1, 0.045, false, true)
        .unwrap();
    let channel =
        runs::run_variant(&c, "channel", "train_mor_tensor_channel", 1, 0.045, false, true)
            .unwrap();
    let fb_tensor = tensor.stats.as_ref().unwrap().overall_fallback_pct();
    let fb_channel = channel.stats.as_ref().unwrap().overall_fallback_pct();
    assert!(
        fb_channel <= fb_tensor + 1e-9,
        "channel {fb_channel}% should not exceed tensor {fb_tensor}%"
    );
    std::fs::remove_dir_all(&c.out_dir).ok();
}

#[test]
fn heatmap_figures_render() {
    let Some(c) = ctx(4, "heat") else { return };
    c.run_experiment("fig11").unwrap();
    c.run_experiment("fig12").unwrap();
    c.run_experiment("fig14").unwrap();
    std::fs::remove_dir_all(&c.out_dir).ok();
}
