//! End-to-end integration over the full stack: Rust coordinator →
//! PJRT train/eval executables → MoR stats, on the tiny preset.
//! Requires `make artifacts-tiny`; tests self-skip if absent.

use mor::coordinator::checkpoint::Checkpoint;
use mor::coordinator::eval::eval_suite;
use mor::coordinator::trainer::{full_mask, Trainer, TrainerOptions};
use mor::data::loader::BatchLoader;
use mor::data::synthetic::CorpusProfile;
use mor::data::tasks::EvalSuite;
use mor::model::config::{ModelConfig, TrainConfig};
use mor::model::naming::{param_specs, QuantTensorId};
use mor::runtime::Runtime;
use std::path::Path;

fn runtime() -> Option<Runtime> {
    let dir = Path::new("artifacts/tiny");
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: artifacts/tiny not built (run `make artifacts-tiny`)");
        return None;
    }
    Some(Runtime::load(dir, ModelConfig::TINY).expect("loading tiny artifacts"))
}

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("mor_it_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn baseline_training_reduces_loss() {
    let Some(rt) = runtime() else { return };
    let mut s = rt.train_session("train_baseline", 42).unwrap();
    let loader = BatchLoader::new(CorpusProfile::Nemotron4Like, 256, s.batch, s.seq, 42, 0);
    let mut first = 0f32;
    let mut last = 0f32;
    for i in 0..25 {
        let b = loader.next_batch();
        let out = s.step(&b.tokens, 3e-3, 0.045).unwrap();
        assert!(out.loss.is_finite(), "step {i} loss {}", out.loss);
        if i == 0 {
            first = out.loss;
        }
        last = out.loss;
    }
    assert!(
        last < first - 0.3,
        "loss should drop: first {first}, last {last}"
    );
    // Baseline emits zero quant stats.
    assert_eq!(s.stats_len, QuantTensorId::count(&ModelConfig::TINY));
}

#[test]
fn mor_block_training_tracks_baseline_and_reports_stats() {
    let Some(rt) = runtime() else { return };
    let mut base = rt.train_session("train_baseline", 7).unwrap();
    let mut mor = rt.train_session("train_mor_tensor_block", 7).unwrap();
    let loader = BatchLoader::new(CorpusProfile::Nemotron4Like, 256, base.batch, base.seq, 7, 0);
    let (mut lb, mut lm) = (0f32, 0f32);
    let mut saw_quant = false;
    for _ in 0..20 {
        let b = loader.next_batch();
        lb = base.step(&b.tokens, 2e-3, 0.045).unwrap().loss;
        let out = mor.step(&b.tokens, 2e-3, 0.045).unwrap();
        lm = out.loss;
        assert_eq!(out.relerr.len(), QuantTensorId::count(&ModelConfig::TINY));
        // relerr slots populated with sane values; fallback is 0/1 for
        // the tensor-level recipe.
        for (re, fb) in out.relerr.iter().zip(out.fallback.iter()) {
            assert!((0.0..1.0).contains(re), "relerr {re}");
            assert!(*fb == 0.0 || *fb == 1.0, "fallback {fb}");
        }
        if out.fallback.iter().any(|f| *f == 0.0) {
            saw_quant = true;
        }
    }
    assert!(saw_quant, "MoR never quantized anything");
    // Same data, same seed: fake-quant noise should keep losses close.
    assert!(
        (lb - lm).abs() < 0.15 * lb.abs().max(0.1),
        "baseline {lb} vs MoR {lm} diverged"
    );
}

#[test]
fn subtensor_fallback_is_fractional() {
    let Some(rt) = runtime() else { return };
    let mut s = rt.train_session("train_mor_subtensor_two_way", 11).unwrap();
    let loader = BatchLoader::new(CorpusProfile::NemotronHLike, 256, s.batch, s.seq, 11, 0);
    let b = loader.next_batch();
    let out = s.step(&b.tokens, 1e-3, 0.045).unwrap();
    for fb in &out.fallback {
        assert!((0.0..=1.0).contains(fb));
    }
}

#[test]
fn eval_session_and_suite_run() {
    let Some(rt) = runtime() else { return };
    let mut s = rt.train_session("train_baseline", 3).unwrap();
    let ev = rt.eval_session("eval").unwrap();
    let loader = BatchLoader::new(CorpusProfile::Nemotron4Like, 256, ev.batch, ev.seq, 3, 1);
    let b = loader.next_batch();
    let mask = full_mask(ev.batch, ev.seq);
    let (loss, acc) = ev.eval(s.param_literals(), &b.tokens, &mask).unwrap();
    assert!(loss > 0.0 && loss.is_finite());
    assert!((0.0..=1.0).contains(&acc));
    // Untrained model ≈ chance accuracy (< 5% over 256 tokens).
    assert!(acc < 0.05, "untrained acc {acc}");

    let suite = EvalSuite::new(ev.seq, 256, 4, 99);
    let scores = eval_suite(&ev, s.params_ref(), &suite).unwrap();
    assert_eq!(scores.per_task.len(), 5);
    for (name, loss, acc) in &scores.per_task {
        assert!(loss.is_finite(), "{name}");
        assert!((0.0..=100.0).contains(acc), "{name} acc {acc}");
    }
}

#[test]
fn trainer_end_to_end_with_metrics_and_checkpoint() {
    let Some(rt) = runtime() else { return };
    let out_dir = tmpdir("trainer");
    let trainer = Trainer::new(&rt, TrainConfig::config1(12));
    let mut opts = TrainerOptions::new("train_mor_tensor_block", 12, out_dir.clone());
    opts.val_every = 4;
    opts.suite_every = 6;
    opts.ckpt_every = 5;
    opts.quiet = true;
    let outcome = trainer.run(&opts).unwrap();
    assert_eq!(outcome.records.len(), 12);
    assert!(outcome.final_train_loss.is_finite());
    assert!(outcome.final_val_loss.is_finite());
    assert!(!outcome.suite_history.is_empty());
    assert!(outcome.metrics_path.exists());
    assert!(outcome.stats.overall_fallback_pct() >= 0.0);

    // Checkpoint round-trip through a fresh session.
    let ckpt_path = out_dir.join("train_mor_tensor_block.step5.ckpt");
    assert!(ckpt_path.exists(), "checkpoint not written");
    let ck = Checkpoint::load(&ckpt_path).unwrap();
    assert_eq!(ck.step, 5);
    let specs = param_specs(&ModelConfig::TINY);
    assert_eq!(ck.tensors.len(), specs.len());
    let mut s2 = rt.train_session("train_baseline", 1).unwrap();
    let params: Vec<_> = specs.iter().map(|s| ck.get(&s.name).unwrap().clone()).collect();
    s2.set_params(&params).unwrap();
    let n1 = s2.param_norm().unwrap();
    let expected: f32 = {
        let mut sq = 0f64;
        for (_, t) in &ck.tensors {
            sq += (t.l2() as f64).powi(2);
        }
        sq.sqrt() as f32
    };
    assert!((n1 - expected).abs() < 1e-3 * expected);
    std::fs::remove_dir_all(out_dir).ok();
}

#[test]
fn deterministic_training_given_seed() {
    let Some(rt) = runtime() else { return };
    let run = |seed: u64| -> Vec<f32> {
        let mut s = rt.train_session("train_baseline", seed).unwrap();
        let loader = BatchLoader::new(CorpusProfile::Nemotron4Like, 256, s.batch, s.seq, seed, 0);
        (0..5)
            .map(|_| s.step(&loader.next_batch().tokens, 1e-3, 0.045).unwrap().loss)
            .collect()
    };
    assert_eq!(run(5), run(5));
    assert_ne!(run(5), run(6));
}
