//! The fleet scheduler's proof obligations (the tenancy suite):
//!
//! * **Interleaving is bitwise invisible.** N tenants multiplexed over
//!   one shared pool — suspended at quantum boundaries through the
//!   checkpoint ring, evicted, resumed — each produce exactly the
//!   trajectory, metrics rows (minus the wall-clock `step_ms` column),
//!   decision fractions and final checkpointed state of the same run
//!   executed alone, at 1/2/4/13 threads.
//! * **Containment composes with equivalence.** A fleet where one
//!   tenant carries a fault schedule (guarded NaN-weight rewind)
//!   reproduces each tenant's solo outcome bitwise — the faulted
//!   tenant matches its faulted solo twin, the neighbors match their
//!   clean ones.
//! * **Preemption at adversarial boundaries is safe.** Suspending at
//!   step 0, after one step, mid-quarantine, around a rewind, at the
//!   penultimate and final steps — the stitched run equals the
//!   continuous one bitwise, including guard events and the rewind
//!   budget (the state fingerprint covers the `guard/state` section).
//! * **Fair-share prevents starvation.** One giant tenant among many
//!   tiny ones: everyone completes, and the schedule log shows no
//!   tenant waited longer than its weight-share bound
//!   `ceil(Σ weights / weight_i)` rounds between slices.
//! * **Adaptive quanta are bitwise invisible.** Shrinking the slice
//!   length when the runnable queue overflows the worker cap changes
//!   *when* tenants are preempted, never *what* they compute: an
//!   adaptive fleet equals a fixed-quantum fleet bitwise, per tenant,
//!   at every thread count.

use mor::coordinator::checkpoint::{scan_ring, TrainCheckpoint};
use mor::coordinator::guard::{GuardAction, GuardConfig};
use mor::coordinator::scheduler::{run_fleet, FleetOptions, Tenant};
use mor::coordinator::trainer::{TrainOutcome, Trainer, TrainerOptions};
use mor::faults::parse_faults;
use mor::model::config::{ModelConfig, TrainConfig};
use mor::runtime::Runtime;
use mor::util::par::Parallelism;
use std::path::{Path, PathBuf};

const TENSOR: &str = "train_mor_tensor_block";
const SUBTENSOR: &str = "train_mor_subtensor_three_way";

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("mor_sched_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// The acceptance matrix: 1/2/4/13 threads. (The CI fleet job
/// additionally runs the whole suite under `MOR_THREADS`, which the
/// ambient-handle test below picks up via `Parallelism::auto`.)
fn thread_sweep() -> [(&'static str, Parallelism); 4] {
    [
        ("serial", Parallelism::serial()),
        ("pooled2", Parallelism::pooled(2, 1)),
        ("pooled4", Parallelism::pooled(4, 1)),
        ("pooled13", Parallelism::pooled(13, 1)),
    ]
}

/// Tenant spec for the equivalence fleets: (id, artifact, config_id,
/// steps, weight, faults, guard).
struct Spec {
    id: &'static str,
    artifact: &'static str,
    config_id: u8,
    steps: u64,
    weight: usize,
    faults: Option<&'static str>,
    guarded: bool,
}

impl Spec {
    fn clean(id: &'static str, artifact: &'static str, config_id: u8, steps: u64) -> Spec {
        Spec { id, artifact, config_id, steps, weight: 1, faults: None, guarded: false }
    }

    fn config(&self) -> TrainConfig {
        match self.config_id {
            2 => TrainConfig::config2(self.steps),
            _ => TrainConfig::config1(self.steps),
        }
    }

    fn opts(&self, dir: &Path, par: &Parallelism) -> TrainerOptions {
        let mut o = TrainerOptions::new(self.artifact, self.steps, dir.to_path_buf());
        o.val_every = 1;
        o.ckpt_every = 2;
        o.quiet = true;
        o.parallelism = Some(par.clone());
        if let Some(spec) = self.faults {
            o.faults = parse_faults(Some(spec)).expect("valid fault spec");
        }
        if self.guarded {
            o.guard = Some(GuardConfig::default());
        }
        o
    }

    fn solo(&self, dir: &Path, par: &Parallelism) -> TrainOutcome {
        let rt = Runtime::host(ModelConfig::TINY);
        Trainer::new(&rt, self.config())
            .run(&self.opts(dir, par))
            .expect("solo run completes")
    }
}

/// Newest ring entry = the final checkpoint (written at the last step;
/// every spec here sets `ckpt_every`).
fn final_fingerprint(dir: &Path, artifact: &str) -> u64 {
    let (step, path) = scan_ring(dir, artifact)
        .into_iter()
        .next()
        .unwrap_or_else(|| panic!("no checkpoint ring in {}", dir.display()));
    let ck = TrainCheckpoint::load(&path).expect("final checkpoint loads");
    assert_eq!(ck.step, step);
    ck.state_fingerprint()
}

fn assert_outcomes_bitwise_eq(a: &TrainOutcome, b: &TrainOutcome, what: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{what}: record count");
    for (ra, rb) in a.records.iter().zip(b.records.iter()) {
        assert_eq!(ra.step, rb.step, "{what}");
        assert_eq!(
            ra.train_loss.to_bits(),
            rb.train_loss.to_bits(),
            "{what}: train loss at step {}",
            ra.step
        );
        assert_eq!(
            ra.val_loss.to_bits(),
            rb.val_loss.to_bits(),
            "{what}: val loss at step {}",
            ra.step
        );
        assert_eq!(
            ra.bf16_fallback_rate.to_bits(),
            rb.bf16_fallback_rate.to_bits(),
            "{what}: fallback at step {}",
            ra.step
        );
        assert_eq!(
            ra.mean_relerr.to_bits(),
            rb.mean_relerr.to_bits(),
            "{what}: relerr at step {}",
            ra.step
        );
        assert_eq!(
            ra.param_norm.to_bits(),
            rb.param_norm.to_bits(),
            "{what}: param norm at step {}",
            ra.step
        );
    }
    assert_eq!(
        a.stats.heatmap_csv(),
        b.stats.heatmap_csv(),
        "{what}: decision fractions"
    );
    assert_eq!(a.guard_events, b.guard_events, "{what}: guard events");
}

/// Run `specs` as one interleaved fleet AND as solo runs, then assert
/// per-tenant bitwise equivalence: records (minus step_ms), decision
/// fractions, guard events, and the final checkpoint's timing-free
/// state fingerprint.
fn assert_fleet_matches_solo(
    tag: &str,
    specs: &[Spec],
    par: &Parallelism,
    quantum: u64,
    max_runs: usize,
) {
    let root = tmpdir(tag);
    let tenants: Vec<Tenant> = specs
        .iter()
        .map(|s| {
            Tenant::new(
                s.id,
                ModelConfig::TINY,
                s.config(),
                s.opts(&root.join("fleet").join(s.id), par),
            )
            .with_weight(s.weight)
        })
        .collect();
    let mut fo = FleetOptions::new(par.clone());
    fo.quantum = quantum;
    fo.max_runs = max_runs;
    let fleet = run_fleet(&tenants, &fo).expect("fleet completes");

    for s in specs {
        let report = fleet.tenant(s.id).expect("tenant reported");
        assert!(
            report.completed(),
            "{tag}/{}: tenant failed: {:?}",
            s.id,
            report.error
        );
        let interleaved = report.outcome.as_ref().expect("completed tenant outcome");
        assert_eq!(
            interleaved.records.len() as u64,
            s.steps,
            "{tag}/{}: full trajectory",
            s.id
        );
        let solo_dir = root.join("solo").join(s.id);
        let solo = s.solo(&solo_dir, par);
        assert_outcomes_bitwise_eq(interleaved, &solo, &format!("{tag}/{}", s.id));
        assert_eq!(
            final_fingerprint(&root.join("fleet").join(s.id), s.artifact),
            final_fingerprint(&solo_dir, s.artifact),
            "{tag}/{}: final checkpoint state",
            s.id
        );
        if quantum > 0 && quantum < s.steps {
            assert!(
                report.slices > 1,
                "{tag}/{}: preemption must actually have happened",
                s.id
            );
        }
    }
    std::fs::remove_dir_all(root).ok();
}

// ---------------------------------------------------------------------------
// Interleaved ≡ solo
// ---------------------------------------------------------------------------

/// Three clean tenants (two artifacts, both train configs, distinct
/// lengths and weights), time-sliced two-resident over one shared
/// pool: every tenant reproduces its solo run bitwise, at every
/// thread count in the acceptance matrix.
#[test]
fn interleaved_tenants_match_solo_bitwise() {
    for (label, par) in thread_sweep() {
        let specs = [
            Spec { weight: 3, ..Spec::clean("a", TENSOR, 1, 6) },
            Spec::clean("b", SUBTENSOR, 1, 4),
            Spec { weight: 2, ..Spec::clean("c", TENSOR, 2, 5) },
        ];
        assert_fleet_matches_solo(&format!("eq_{label}"), &specs, &par, 2, 2);
    }
}

/// The ambient-handle variant the CI determinism matrix drives: under
/// `Parallelism::auto()` (which resolves `MOR_THREADS`), a sliced
/// fleet still reproduces the solo runs bitwise.
#[test]
fn interleaved_matches_solo_under_ambient_threads() {
    let par = Parallelism::auto();
    let specs = [
        Spec::clean("amb_a", TENSOR, 1, 4),
        Spec::clean("amb_b", SUBTENSOR, 2, 3),
    ];
    assert_fleet_matches_solo("eq_ambient", &specs, &par, 2, 1);
}

/// Single-tenant fault injection: tenant `b` carries a guarded
/// NaN-weight fault (checkpoint rewind mid-fleet); tenants `a`/`c`
/// are clean. Every tenant — including the faulted one — matches its
/// solo twin bitwise at every thread count, i.e. chaos in one tenant
/// neither perturbs neighbors nor breaks the faulted tenant's own
/// equivalence.
#[test]
fn single_tenant_fault_preserves_fleet_equivalence() {
    for (label, par) in thread_sweep() {
        let specs = [
            Spec::clean("a", TENSOR, 1, 6),
            Spec {
                weight: 2,
                faults: Some("nan:weight@step=3"),
                guarded: true,
                ..Spec::clean("b", TENSOR, 2, 6)
            },
            Spec::clean("c", SUBTENSOR, 1, 4),
        ];
        assert_fleet_matches_solo(&format!("fault_{label}"), &specs, &par, 3, 2);
    }
}

// ---------------------------------------------------------------------------
// Preemption property test
// ---------------------------------------------------------------------------

/// Suspend/evict/resume one guarded, faulted run at adversarial
/// boundaries — step 0 (before anything ran), step 1, step 3
/// (mid-quarantine: the NaN-grad at step 2 quarantines through the
/// run's end), step 5 (just before the NaN-weight rewind at step 5),
/// step 7 (penultimate), step 8 (the final step). The stitched run
/// must equal the continuous one bitwise: records, guard events
/// (skip/quarantine/rewind trail), and the final checkpoint's state
/// fingerprint — which covers the `guard/state` section, so the
/// rewind budget surviving eviction is part of the proof.
#[test]
fn preemption_at_adversarial_boundaries_is_bitwise_invisible() {
    let steps = 8u64;
    let spec = Spec {
        faults: Some("nan:grad@step=3;nan:weight@step=6"),
        guarded: true,
        ..Spec::clean("pre", TENSOR, 1, steps)
    };
    for (label, par) in thread_sweep() {
        let root = tmpdir(&format!("preempt_{label}"));
        let continuous = spec.solo(&root.join("cont"), &par);
        // The fault trail this test depends on: one skip+quarantine
        // (NaN grad), one rewind (NaN weight).
        assert!(
            continuous
                .guard_events
                .iter()
                .any(|e| e.action == GuardAction::SkipStep),
            "{label}: NaN grad must skip-step"
        );
        assert_eq!(
            continuous
                .guard_events
                .iter()
                .filter(|e| e.action == GuardAction::Rewind)
                .count(),
            1,
            "{label}: NaN weight must rewind exactly once"
        );

        let seg_dir = root.join("seg");
        let mut last: Option<TrainOutcome> = None;
        for stop in [0u64, 1, 3, 5, 7, steps] {
            // Eviction between iterations: runtime, trainer, session,
            // loaders and guard are all rebuilt from disk each segment.
            let rt = Runtime::host(ModelConfig::TINY);
            let mut o = spec.opts(&seg_dir, &par);
            o.auto_resume = true;
            o.stop_after = Some(stop);
            last = Some(Trainer::new(&rt, spec.config()).run(&o).unwrap_or_else(|e| {
                panic!("{label}: segment to step {stop} failed: {e:#}")
            }));
        }
        let stitched = last.expect("segments ran");
        assert_outcomes_bitwise_eq(&stitched, &continuous, &format!("preempt_{label}"));
        assert_eq!(
            final_fingerprint(&seg_dir, spec.artifact),
            final_fingerprint(&root.join("cont"), spec.artifact),
            "{label}: final checkpoint state (incl. guard rewind budget)"
        );
        std::fs::remove_dir_all(root).ok();
    }
}

// ---------------------------------------------------------------------------
// Adaptive quanta ≡ fixed quanta
// ---------------------------------------------------------------------------

/// `--adaptive` divides the quantum by the queue-over-cap ratio (three
/// runnable tenants over a one-run cap → quantum 4 becomes 1), so
/// oversubscribed rounds cycle tenants faster. Preemption points move;
/// the computation must not: every tenant of the adaptive fleet equals
/// its fixed-quantum twin bitwise — records, decision fractions, guard
/// events and final checkpoint state — at every thread count.
#[test]
fn adaptive_quanta_match_fixed_quanta_bitwise() {
    for (label, par) in thread_sweep() {
        let root = tmpdir(&format!("adaptive_{label}"));
        let specs = [
            Spec::clean("a", TENSOR, 1, 6),
            Spec::clean("b", SUBTENSOR, 1, 4),
            Spec { weight: 2, ..Spec::clean("c", TENSOR, 2, 5) },
        ];
        let run = |sub: &str, adaptive: bool| {
            let tenants: Vec<Tenant> = specs
                .iter()
                .map(|s| {
                    Tenant::new(
                        s.id,
                        ModelConfig::TINY,
                        s.config(),
                        s.opts(&root.join(sub).join(s.id), &par),
                    )
                    .with_weight(s.weight)
                })
                .collect();
            let mut fo = FleetOptions::new(par.clone());
            fo.quantum = 4;
            fo.max_runs = 1;
            fo.adaptive = adaptive;
            run_fleet(&tenants, &fo).expect("fleet completes")
        };
        let fixed = run("fixed", false);
        let adaptive = run("adaptive", true);

        for s in &specs {
            let f = fixed.tenant(s.id).expect("fixed tenant reported");
            let a = adaptive.tenant(s.id).expect("adaptive tenant reported");
            assert!(f.completed(), "{label}/{}: fixed failed: {:?}", s.id, f.error);
            assert!(a.completed(), "{label}/{}: adaptive failed: {:?}", s.id, a.error);
            assert_outcomes_bitwise_eq(
                a.outcome.as_ref().unwrap(),
                f.outcome.as_ref().unwrap(),
                &format!("adaptive_{label}/{}", s.id),
            );
            assert_eq!(
                final_fingerprint(&root.join("adaptive").join(s.id), s.artifact),
                final_fingerprint(&root.join("fixed").join(s.id), s.artifact),
                "{label}/{}: final checkpoint state",
                s.id
            );
        }
        // The shrunk quantum really bit: with three runnable tenants
        // over a one-run cap the adaptive fleet runs 1-step slices
        // while oversubscribed, so it takes strictly more slices.
        let slices = |fo: &mor::coordinator::scheduler::FleetOutcome| {
            fo.tenants.iter().map(|t| t.slices).sum::<u64>()
        };
        assert!(
            slices(&adaptive) > slices(&fixed),
            "{label}: adaptive must preempt more often ({} vs {})",
            slices(&adaptive),
            slices(&fixed)
        );
        std::fs::remove_dir_all(root).ok();
    }
}

// ---------------------------------------------------------------------------
// Fair share / starvation
// ---------------------------------------------------------------------------

/// The 1-giant + 12-tiny shape: a weight-12 tenant that needs 6 slices
/// among 12 weight-1 single-slice tenants, 4 resident per round. All
/// 13 must complete, and the schedule log must show no tenant waited
/// more than `ceil(Σ weights / weight_i)` rounds between slices.
#[test]
fn fair_share_schedules_giant_and_tiny_tenants_without_starvation() {
    let root = tmpdir("starve");
    let par = Parallelism::pooled(4, 1);
    let giant_steps = 18u64;
    let tiny_steps = 3u64;
    let mut tenants = Vec::new();
    {
        let mut o = TrainerOptions::new(TENSOR, giant_steps, root.join("giant"));
        o.val_every = 0;
        o.quiet = true;
        o.parallelism = Some(par.clone());
        tenants.push(
            Tenant::new("giant", ModelConfig::TINY, TrainConfig::config1(giant_steps), o)
                .with_weight(12),
        );
    }
    for i in 0..12 {
        let id = format!("tiny{i}");
        let mut o = TrainerOptions::new(TENSOR, tiny_steps, root.join(&id));
        o.val_every = 0;
        o.quiet = true;
        o.parallelism = Some(par.clone());
        tenants.push(Tenant::new(
            &id,
            ModelConfig::TINY,
            TrainConfig::config1(tiny_steps),
            o,
        ));
    }
    let mut fo = FleetOptions::new(par);
    fo.quantum = 3;
    fo.max_runs = 4;
    let fleet = run_fleet(&tenants, &fo).expect("starvation fleet completes");

    let total_weight: usize = tenants.iter().map(|t| t.weight).sum();
    assert_eq!(total_weight, 24);
    for (i, t) in tenants.iter().enumerate() {
        let report = &fleet.tenants[i];
        assert!(
            report.completed(),
            "{}: failed: {:?}",
            t.id,
            report.error
        );
        let got = report.outcome.as_ref().unwrap().records.len() as u64;
        assert_eq!(got, t.opts.steps, "{}: must run to completion", t.id);
        let bound = (total_weight as u64).div_ceil(t.weight as u64);
        let waited = fleet.max_wait_rounds(i);
        assert!(
            waited <= bound,
            "{}: waited {waited} rounds, weight-share bound is {bound}",
            t.id
        );
    }
    // The giant needed multiple slices (preemption really happened);
    // each tiny fit in one.
    assert_eq!(fleet.tenants[0].slices, giant_steps / fo.quantum);
    assert!(fleet.tenants[1..].iter().all(|t| t.slices == 1));
    // The log accounts for every slice of every tenant.
    assert_eq!(
        fleet.schedule.len() as u64,
        fleet.tenants.iter().map(|t| t.slices).sum::<u64>()
    );
    std::fs::remove_dir_all(root).ok();
}
