//! The robustness layer's proof obligations:
//!
//! * the fault-spec and guard-spec grammars are strict — a full
//!   accept/reject matrix, with canonical `describe()` round-trips;
//! * a **fault-free guarded run is bitwise identical to an unguarded
//!   one** at 1, 2 and 13 threads (the quarantine wrapper is
//!   transparent while empty);
//! * every fault class is detected and survived: seeded NaN gradients
//!   are skip-stepped and quarantined, seeded NaN weights and worker
//!   panics trigger a checkpoint rewind whose recovered trajectory is
//!   **bitwise identical to a clean run**, block bit-flips are caught
//!   and quarantined, torn checkpoint saves are walked past, repeating
//!   panics burn one rewind per refire (and exhaust the budget loudly
//!   when they outlast it), and the stall fault self-preempts instead
//!   of hanging;
//! * the checkpoint ring is crash-safe: CRC-corrupt and torn files are
//!   detected by `TrainCheckpoint::load`, `--auto-resume` walks the
//!   ring newest → oldest past them (sweeping stale save temps), and
//!   `--ckpt-keep` prunes retention;
//! * chaos is **contained at fleet scope**: killing, NaN-seeding or
//!   torn-saving one tenant of a multiplexed fleet mid-flight leaves
//!   every surviving tenant bitwise identical to its solo run, at 1,
//!   4 and 13 threads.

use mor::coordinator::checkpoint::{scan_ring, TrainCheckpoint};
use mor::coordinator::guard::{parse_guard, GuardAction, GuardConfig};
use mor::coordinator::scheduler::{run_fleet, FleetOptions, Tenant};
use mor::coordinator::trainer::{TrainOutcome, Trainer, TrainerOptions};
use mor::faults::parse_faults;
use mor::model::config::{ModelConfig, TrainConfig};
use mor::runtime::Runtime;
use mor::util::par::Parallelism;
use std::path::PathBuf;

const ARTIFACT: &str = "train_mor_tensor_block";

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("mor_chaos_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// A short host training run with chaos-specific options layered on by
/// the `tweak` closure. The out dir is the caller's to clean up (the
/// ring tests inspect it after the run).
fn run_in(
    dir: &std::path::Path,
    artifact: &str,
    steps: u64,
    par: &Parallelism,
    tweak: impl FnOnce(&mut TrainerOptions),
) -> anyhow::Result<TrainOutcome> {
    let rt = Runtime::host(ModelConfig::TINY);
    let trainer = Trainer::new(&rt, TrainConfig::config1(steps));
    let mut opts = TrainerOptions::new(artifact, steps, dir.to_path_buf());
    opts.val_every = 1;
    opts.quiet = true;
    opts.parallelism = Some(par.clone());
    tweak(&mut opts);
    trainer.run(&opts)
}

fn guarded(opts: &mut TrainerOptions) {
    opts.guard = Some(GuardConfig::default());
}

fn with_faults(opts: &mut TrainerOptions, spec: &str) {
    opts.faults = parse_faults(Some(spec)).expect("valid fault spec");
}

fn thread_sweep() -> [(&'static str, Parallelism); 3] {
    [
        ("serial", Parallelism::serial()),
        ("pooled2", Parallelism::pooled(2, 1)),
        ("pooled13", Parallelism::pooled(13, 1)),
    ]
}

fn count(outcome: &TrainOutcome, action: GuardAction) -> usize {
    outcome.guard_events.iter().filter(|e| e.action == action).count()
}

fn assert_outcomes_bitwise_eq(a: &TrainOutcome, b: &TrainOutcome, what: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{what}: record count");
    for (ra, rb) in a.records.iter().zip(b.records.iter()) {
        assert_eq!(ra.step, rb.step, "{what}");
        assert_eq!(
            ra.train_loss.to_bits(),
            rb.train_loss.to_bits(),
            "{what}: train loss at step {}",
            ra.step
        );
        assert_eq!(
            ra.val_loss.to_bits(),
            rb.val_loss.to_bits(),
            "{what}: val loss at step {}",
            ra.step
        );
        assert_eq!(
            ra.bf16_fallback_rate.to_bits(),
            rb.bf16_fallback_rate.to_bits(),
            "{what}: fallback at step {}",
            ra.step
        );
        assert_eq!(
            ra.mean_relerr.to_bits(),
            rb.mean_relerr.to_bits(),
            "{what}: relerr at step {}",
            ra.step
        );
        assert_eq!(
            ra.param_norm.to_bits(),
            rb.param_norm.to_bits(),
            "{what}: param norm at step {}",
            ra.step
        );
    }
}

// ---------------------------------------------------------------------------
// Grammar
// ---------------------------------------------------------------------------

#[test]
fn fault_grammar_accepts_and_round_trips() {
    assert!(parse_faults(None).unwrap().is_none());
    let spec = parse_faults(Some(
        "nan:grad@step=7;inf:weight@step=9;bitflip:block@p=1e-4;panic:worker@step=11;\
         repeat-panic:worker@step=5,count=3;stall:step@step=4;torn-save@ckpt=2",
    ))
    .unwrap()
    .unwrap();
    assert_eq!(spec.faults.len(), 7);
    // Canonical spelling round-trips (1e-4 normalizes to 0.0001).
    let canon = spec.describe();
    assert_eq!(
        canon,
        "nan:grad@step=7;inf:weight@step=9;bitflip:block@p=0.0001;panic:worker@step=11;\
         repeat-panic:worker@step=5,count=3;stall:step@step=4;torn-save@ckpt=2"
    );
    assert_eq!(parse_faults(Some(&canon)).unwrap().unwrap(), spec);
    // repeat-panic's comma args canonicalize step-first.
    let swapped = parse_faults(Some("repeat-panic:worker@count=3,step=5")).unwrap().unwrap();
    assert_eq!(swapped.describe(), "repeat-panic:worker@step=5,count=3");
    // Entry-level whitespace is tolerated.
    let ws = parse_faults(Some(" nan:grad@step=7 ; inf:grad@step=2 ")).unwrap().unwrap();
    assert_eq!(ws.faults.len(), 2);
    // Boundary probability: p=1 is legal (every block hit).
    assert!(parse_faults(Some("bitflip:block@p=1")).is_ok());
}

#[test]
fn fault_grammar_rejects_malformed() {
    for bad in [
        "",                       // empty spec
        ";",                      // empty entries
        "nan:grad@step=7;",       // trailing empty entry
        "nan@step=7",             // seed without a site
        "nan:tensor@step=7",      // unknown seed site
        "nan:grad",               // missing '@'
        "nan:grad@step",          // argument is not key=value
        "nan:grad@step=0",        // before the first step
        "nan:grad@step=x",        // non-numeric
        "nan:grad@p=3",           // wrong key for a seed
        "bitflip@p=0.5",          // bitflip without the block site
        "bitflip:worker@p=0.5",   // wrong bitflip site
        "bitflip:block@p=0",      // zero probability never fires
        "bitflip:block@p=1.5",    // out of (0, 1]
        "bitflip:block@p=-0.1",   // negative
        "bitflip:block@p=nan",    // non-finite
        "bitflip:block@step=3",   // wrong key for bitflip
        "panic@step=3",           // panic without the worker site
        "panic:block@step=3",     // wrong panic site
        "panic:worker@step=0",    // before the first step
        "torn-save:block@ckpt=1", // torn-save takes no site
        "torn-save@step=1",       // wrong key for torn-save
        "torn-save@ckpt=0",       // save indices are 1-based
        "blort:worker@step=3",    // unknown fault kind
        "repeat-panic@step=5,count=2",          // missing worker site
        "repeat-panic:block@step=5,count=2",    // wrong site
        "repeat-panic:worker@step=5",           // count is required
        "repeat-panic:worker@count=2",          // step is required
        "repeat-panic:worker@step=0,count=2",   // before the first step
        "repeat-panic:worker@step=5,count=0",   // zero refires never fire
        "repeat-panic:worker@step=5,count=2,step=6", // duplicate key
        "repeat-panic:worker@step=5,blort=2",   // unknown key
        "stall@step=4",        // stall without the step site
        "stall:worker@step=4", // wrong stall site
        "stall:step@ckpt=4",   // wrong key for stall
        "stall:step@step=0",   // before the first step
    ] {
        assert!(parse_faults(Some(bad)).is_err(), "spec {bad:?} must be rejected");
    }
}

#[test]
fn guard_grammar_accepts_and_rejects() {
    assert!(parse_guard(None).unwrap().is_none());
    assert!(parse_guard(Some("off")).unwrap().is_none());
    assert_eq!(parse_guard(Some("on")).unwrap().unwrap(), GuardConfig::default());
    let cfg = parse_guard(Some("skip=1,quarantine=4,rewinds=2,spike=5")).unwrap().unwrap();
    assert_eq!(cfg.skip_limit, 1);
    assert_eq!(cfg.quarantine_steps, 4);
    assert_eq!(cfg.max_rewinds, 2);
    assert_eq!(cfg.spike_factor, 5.0);
    // `on` composes with overrides; describe() round-trips.
    let composed = parse_guard(Some("on,quarantine=4")).unwrap().unwrap();
    assert_eq!(composed.quarantine_steps, 4);
    assert_eq!(composed.skip_limit, GuardConfig::default().skip_limit);
    assert_eq!(parse_guard(Some(&cfg.describe())).unwrap().unwrap(), cfg);
    for bad in [
        "",             // empty
        "banana",       // not a setting
        "skip",         // not key=value
        "skip=x",       // non-numeric
        "quarantine=0", // zero-length demotion
        "rewinds=-1",   // negative
        "spike=1.0",    // must be > 1
        "spike=0.5",    // must be > 1
        "spike=inf",    // must be finite
        "spike=nan",    // must be finite
        "off,skip=1",   // off cannot be combined
        "skip=1,,",     // empty setting
    ] {
        assert!(parse_guard(Some(bad)).is_err(), "guard spec {bad:?} must be rejected");
    }
}

// ---------------------------------------------------------------------------
// The transparency contract
// ---------------------------------------------------------------------------

/// With no faults and no anomalies, arming the guard changes nothing:
/// the quarantine wrapper is empty, the skip scan counts zero, and the
/// run is bitwise identical to an unguarded one at any thread count.
#[test]
fn fault_free_guarded_equals_unguarded_bitwise() {
    for (label, par) in thread_sweep() {
        let d_plain = tmpdir(&format!("plain_{label}"));
        let d_guard = tmpdir(&format!("guard_{label}"));
        let plain = run_in(&d_plain, ARTIFACT, 4, &par, |_| {}).unwrap();
        let armed = run_in(&d_guard, ARTIFACT, 4, &par, guarded).unwrap();
        assert_outcomes_bitwise_eq(&plain, &armed, label);
        assert!(armed.guard_events.is_empty(), "{label}: no interventions expected");
        std::fs::remove_dir_all(d_plain).ok();
        std::fs::remove_dir_all(d_guard).ok();
    }
}

// ---------------------------------------------------------------------------
// Fault classes: detect and survive
// ---------------------------------------------------------------------------

/// A seeded NaN gradient is caught by the pre-update scan: the update
/// is skipped (optimizer state untouched), the tensors are quarantined
/// to BF16, and the run finishes with a finite loss — at 1, 2 and 13
/// threads. Without the guard the same fault corrupts the parameters
/// and the loss goes (and stays) non-finite.
#[test]
fn nan_grad_fault_is_skipped_and_survived() {
    for (label, par) in thread_sweep() {
        let dir = tmpdir(&format!("nangrad_{label}"));
        let out = run_in(&dir, ARTIFACT, 6, &par, |o| {
            guarded(o);
            with_faults(o, "nan:grad@step=3");
        })
        .unwrap();
        assert!(
            out.final_train_loss.is_finite(),
            "{label}: guarded run must end finite, got {}",
            out.final_train_loss
        );
        assert!(count(&out, GuardAction::SkipStep) >= 1, "{label}: expected a skip");
        assert!(
            count(&out, GuardAction::Quarantine) >= 1,
            "{label}: expected a quarantine"
        );
        assert_eq!(count(&out, GuardAction::Rewind), 0, "{label}: no rewind needed");
        // The intervention log lands next to the metrics.
        let gcsv = dir.join(format!("{ARTIFACT}.config1.guard.csv"));
        let text = std::fs::read_to_string(&gcsv).expect("guard.csv written");
        assert!(text.starts_with("step,action,detail\n"), "guard.csv header");
        assert!(text.contains("skip_step"), "guard.csv records the skip");
        std::fs::remove_dir_all(dir).ok();
    }

    // Control: the identical fault without a guard poisons the run.
    let dir = tmpdir("nangrad_unguarded");
    let out = run_in(&dir, ARTIFACT, 6, &Parallelism::serial(), |o| {
        with_faults(o, "nan:grad@step=3");
    })
    .unwrap();
    assert!(
        !out.final_train_loss.is_finite(),
        "unguarded run should end non-finite, got {}",
        out.final_train_loss
    );
    std::fs::remove_dir_all(dir).ok();
}

/// A NaN seeded into the *parameters* (post-update) cannot be skipped
/// away — the guard rewinds to the last good checkpoint, and because
/// the consumed one-shot fault does not re-fire, the recovered
/// trajectory is bitwise identical to a clean guarded run.
#[test]
fn weight_nan_rewind_recovers_bitwise() {
    for (label, par) in thread_sweep() {
        let d_clean = tmpdir(&format!("wnan_clean_{label}"));
        let d_fault = tmpdir(&format!("wnan_fault_{label}"));
        let clean = run_in(&d_clean, ARTIFACT, 8, &par, |o| {
            guarded(o);
            o.ckpt_every = 2;
        })
        .unwrap();
        let recovered = run_in(&d_fault, ARTIFACT, 8, &par, |o| {
            guarded(o);
            o.ckpt_every = 2;
            with_faults(o, "nan:weight@step=5");
        })
        .unwrap();
        assert_outcomes_bitwise_eq(&clean, &recovered, label);
        assert_eq!(count(&recovered, GuardAction::Rewind), 1, "{label}: one rewind");
        assert!(recovered.records.iter().all(|r| r.param_norm.is_finite()), "{label}");
        std::fs::remove_dir_all(d_clean).ok();
        std::fs::remove_dir_all(d_fault).ok();
    }
}

/// A worker panic mid-step unwinds out of the parallel section without
/// committing anything; the guard catches the panic, rewinds, and the
/// replayed trajectory is bitwise identical to a clean guarded run —
/// on the serial path and on 2- and 13-thread pools.
#[test]
fn worker_panic_rewind_recovers_bitwise() {
    for (label, par) in thread_sweep() {
        let d_clean = tmpdir(&format!("panic_clean_{label}"));
        let d_fault = tmpdir(&format!("panic_fault_{label}"));
        let clean = run_in(&d_clean, ARTIFACT, 8, &par, |o| {
            guarded(o);
            o.ckpt_every = 2;
        })
        .unwrap();
        let recovered = run_in(&d_fault, ARTIFACT, 8, &par, |o| {
            guarded(o);
            o.ckpt_every = 2;
            with_faults(o, "panic:worker@step=5");
        })
        .unwrap();
        assert_outcomes_bitwise_eq(&clean, &recovered, label);
        assert_eq!(count(&recovered, GuardAction::Rewind), 1, "{label}: one rewind");
        std::fs::remove_dir_all(d_clean).ok();
        std::fs::remove_dir_all(d_fault).ok();
    }
}

/// `repeat-panic:worker@step=N,count=K` re-fires on the first K
/// attempts of step N — including the guard's rewind replays. With K
/// within the rewind budget the guard burns exactly K rewinds and the
/// recovered trajectory is bitwise identical to a clean guarded run.
#[test]
fn repeat_panic_within_guard_budget_recovers_bitwise() {
    for (label, par) in thread_sweep() {
        let d_clean = tmpdir(&format!("rpanic_clean_{label}"));
        let d_fault = tmpdir(&format!("rpanic_fault_{label}"));
        let clean = run_in(&d_clean, ARTIFACT, 8, &par, |o| {
            guarded(o);
            o.ckpt_every = 2;
        })
        .unwrap();
        let recovered = run_in(&d_fault, ARTIFACT, 8, &par, |o| {
            guarded(o);
            o.ckpt_every = 2;
            with_faults(o, "repeat-panic:worker@step=5,count=2");
        })
        .unwrap();
        assert_outcomes_bitwise_eq(&clean, &recovered, label);
        assert_eq!(count(&recovered, GuardAction::Rewind), 2, "{label}: two rewinds");
        std::fs::remove_dir_all(d_clean).ok();
        std::fs::remove_dir_all(d_fault).ok();
    }
}

/// With more refires than the rewind budget, every replay panics again
/// and the guard gives up loudly — the error names the exhausted
/// budget (the supervisor's cue to demote rather than retry).
#[test]
fn repeat_panic_beyond_budget_exhausts_the_guard() {
    let dir = tmpdir("rpanic_exhaust");
    let err = run_in(&dir, ARTIFACT, 8, &Parallelism::serial(), |o| {
        guarded(o);
        o.ckpt_every = 2;
        with_faults(o, "repeat-panic:worker@step=5,count=5");
    })
    .expect_err("unsurvivable refire count must fail the run");
    let text = format!("{err:#}");
    assert!(
        text.contains("exhausted its rewind budget"),
        "error names the exhausted budget, got {text:?}"
    );
    std::fs::remove_dir_all(dir).ok();
}

/// The stall fault self-preempts instead of hanging: the "wedged" step
/// polls the cooperative stop flag for a bounded budget, checkpoints
/// the finished prefix, and ends the run early — and auto-resume later
/// completes the trajectory bitwise identical to an unstalled run.
#[test]
fn stall_fault_self_preempts_without_hanging() {
    let par = Parallelism::serial();
    let d_clean = tmpdir("stall_clean");
    let d_stall = tmpdir("stall_fault");
    let clean = run_in(&d_clean, ARTIFACT, 6, &par, |o| o.ckpt_every = 2).unwrap();
    let stalled = run_in(&d_stall, ARTIFACT, 6, &par, |o| {
        o.ckpt_every = 2;
        with_faults(o, "stall:step@step=3");
    })
    .unwrap();
    assert_eq!(stalled.records.len(), 2, "two steps finish before the stall");
    // The suspension checkpoint captured the finished prefix.
    assert!(TrainCheckpoint::load(&d_stall.join(format!("{ARTIFACT}.step2.ckpt"))).is_ok());
    // A fault-free auto-resume completes the trajectory bitwise.
    let resumed = run_in(&d_stall, ARTIFACT, 6, &par, |o| {
        o.ckpt_every = 2;
        o.auto_resume = true;
    })
    .unwrap();
    assert_outcomes_bitwise_eq(&clean, &resumed, "resume after stall");
    std::fs::remove_dir_all(d_clean).ok();
    std::fs::remove_dir_all(d_stall).ok();
}

/// Silent block corruption (an exponent bit-flip in every quantized
/// block, p=1) blows up the first step's numerics; the guard skips the
/// poisoned update and quarantines everything to BF16, after which the
/// fault has no remaining surface — the run finishes finite without
/// spending a rewind.
#[test]
fn bitflip_fault_is_quarantined_and_survived() {
    for (label, par) in thread_sweep() {
        let dir = tmpdir(&format!("bitflip_{label}"));
        let out = run_in(&dir, "train_mor_subtensor_three_way", 6, &par, |o| {
            guarded(o);
            with_faults(o, "bitflip:block@p=1");
        })
        .unwrap();
        assert!(
            out.final_train_loss.is_finite(),
            "{label}: guarded run must end finite, got {}",
            out.final_train_loss
        );
        assert!(count(&out, GuardAction::SkipStep) >= 1, "{label}: expected a skip");
        assert!(
            count(&out, GuardAction::Quarantine) >= 1,
            "{label}: expected a quarantine"
        );
        assert_eq!(count(&out, GuardAction::Rewind), 0, "{label}: no rewind needed");
        std::fs::remove_dir_all(dir).ok();
    }
}

/// The torn-save fault truncates one ring entry mid-write; training is
/// unaffected (the torn file just sits there unloadable), and
/// auto-resume later walks past it to the newest intact checkpoint.
#[test]
fn torn_save_fault_is_survived_by_auto_resume() {
    let par = Parallelism::serial();
    let d_clean = tmpdir("torn_clean");
    let d_fault = tmpdir("torn_fault");
    let clean = run_in(&d_clean, ARTIFACT, 8, &par, |o| o.ckpt_every = 2).unwrap();
    let torn = run_in(&d_fault, ARTIFACT, 8, &par, |o| {
        o.ckpt_every = 2;
        with_faults(o, "torn-save@ckpt=2");
    })
    .unwrap();
    // The fault only damages the ring, never the trajectory.
    assert_outcomes_bitwise_eq(&clean, &torn, "torn-save");
    let p4 = d_fault.join(format!("{ARTIFACT}.step4.ckpt"));
    assert!(TrainCheckpoint::load(&p4).is_err(), "2nd save (step4) must be torn");
    assert!(TrainCheckpoint::load(&d_fault.join(format!("{ARTIFACT}.step2.ckpt"))).is_ok());
    assert!(TrainCheckpoint::load(&d_fault.join(format!("{ARTIFACT}.step6.ckpt"))).is_ok());

    // Strand the run before the torn entry: only step2 (good) and
    // step4 (torn) remain. Auto-resume must skip step4, restart from
    // step2, and land bitwise on the continuous trajectory.
    std::fs::remove_file(d_fault.join(format!("{ARTIFACT}.step6.ckpt"))).unwrap();
    std::fs::remove_file(d_fault.join(format!("{ARTIFACT}.step8.ckpt"))).unwrap();
    let resumed = run_in(&d_fault, ARTIFACT, 8, &par, |o| {
        o.ckpt_every = 2;
        o.auto_resume = true;
    })
    .unwrap();
    assert_outcomes_bitwise_eq(&clean, &resumed, "auto-resume past torn");
    std::fs::remove_dir_all(d_clean).ok();
    std::fs::remove_dir_all(d_fault).ok();
}

// ---------------------------------------------------------------------------
// The crash-safe ring
// ---------------------------------------------------------------------------

/// CRC-corrupt and torn ring entries are detected at load; auto-resume
/// sweeps stale save temps and walks newest → oldest to the first
/// loadable checkpoint, and the resumed run is bitwise identical to
/// the uninterrupted one.
#[test]
fn auto_resume_walks_past_corrupt_and_torn_ring_entries() {
    let par = Parallelism::serial();
    let d_cont = tmpdir("ring_cont");
    let d_ring = tmpdir("ring");
    let continuous = run_in(&d_cont, ARTIFACT, 8, &par, |o| o.ckpt_every = 2).unwrap();
    run_in(&d_ring, ARTIFACT, 8, &par, |o| o.ckpt_every = 2).unwrap();

    // Corrupt the newest entry with a mid-file bit-flip: the CRC
    // trailer must reject it.
    let p8 = d_ring.join(format!("{ARTIFACT}.step8.ckpt"));
    let mut bytes = std::fs::read(&p8).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x01;
    std::fs::write(&p8, &bytes).unwrap();
    assert!(TrainCheckpoint::load(&p8).is_err(), "bit-flipped checkpoint must not load");

    // Tear the next one (simulated crash mid-write).
    let p6 = d_ring.join(format!("{ARTIFACT}.step6.ckpt"));
    let b6 = std::fs::read(&p6).unwrap();
    std::fs::write(&p6, &b6[..b6.len() / 2]).unwrap();
    assert!(TrainCheckpoint::load(&p6).is_err(), "torn checkpoint must not load");

    // And leave a stale save temp from a "killed" process.
    let stale = d_ring.join(format!("{ARTIFACT}.step9.ckpt.tmp.4242"));
    std::fs::write(&stale, b"junk").unwrap();

    // Auto-resume: walks 8 (corrupt) -> 6 (torn) -> 4 (loads), sweeps
    // the temp, and finishes the run bitwise-identically.
    let resumed = run_in(&d_ring, ARTIFACT, 8, &par, |o| {
        o.ckpt_every = 2;
        o.auto_resume = true;
    })
    .unwrap();
    assert_outcomes_bitwise_eq(&continuous, &resumed, "auto-resume");
    assert!(!stale.exists(), "stale temp file must be swept");
    std::fs::remove_dir_all(d_cont).ok();
    std::fs::remove_dir_all(d_ring).ok();
}

// ---------------------------------------------------------------------------
// Multi-run chaos: one tenant misbehaves, the fleet does not
// ---------------------------------------------------------------------------

/// The fleet containment matrix: 1, 4 and 13 threads (the shared pool
/// every tenant slice is multiplexed over).
fn fleet_sweep() -> [(&'static str, Parallelism); 3] {
    [
        ("serial", Parallelism::serial()),
        ("pooled4", Parallelism::pooled(4, 1)),
        ("pooled13", Parallelism::pooled(13, 1)),
    ]
}

/// A three-tenant fleet (time-sliced, two resident) where the middle
/// tenant runs with `victim_tweak` layered on; returns the fleet
/// outcome, after asserting both neighbors completed bitwise identical
/// to their solo runs. The victim's verdict is the caller's to assert.
fn fleet_with_victim(
    tag: &str,
    par: &Parallelism,
    victim_tweak: impl Fn(&mut TrainerOptions),
) -> mor::coordinator::scheduler::FleetOutcome {
    let root = tmpdir(tag);
    let steps = 6u64;
    let mk = |id: &str, tweak: &dyn Fn(&mut TrainerOptions)| {
        let mut opts = TrainerOptions::new(ARTIFACT, steps, root.join("fleet").join(id));
        opts.val_every = 1;
        opts.quiet = true;
        opts.parallelism = Some(par.clone());
        tweak(&mut opts);
        Tenant::new(id, ModelConfig::TINY, TrainConfig::config1(steps), opts)
    };
    let nop: &dyn Fn(&mut TrainerOptions) = &|_| {};
    let tenants = [mk("left", nop), mk("victim", &|o| victim_tweak(o)), mk("right", nop)];
    let mut fo = FleetOptions::new(par.clone());
    fo.quantum = 2;
    fo.max_runs = 2;
    let fleet = run_fleet(&tenants, &fo).expect("fleet itself must not die");

    for id in ["left", "right"] {
        let report = fleet.tenant(id).expect("neighbor reported");
        assert!(report.completed(), "{tag}/{id}: neighbor failed: {:?}", report.error);
        let solo = run_in(&root.join("solo").join(id), ARTIFACT, steps, par, |_| {})
            .expect("solo neighbor run");
        assert_outcomes_bitwise_eq(
            report.outcome.as_ref().unwrap(),
            &solo,
            &format!("{tag}/{id}"),
        );
    }
    std::fs::remove_dir_all(root).ok();
    fleet
}

/// Kill one tenant: an *unguarded* injected worker panic aborts the
/// victim's slice. The fleet contains it — the victim is reported
/// failed with the panic text, and both neighbors (sharing the pool
/// the panic unwound through) finish bitwise identical to solo runs.
#[test]
fn fleet_contains_an_unguarded_worker_panic_kill() {
    for (label, par) in fleet_sweep() {
        let fleet = fleet_with_victim(&format!("mr_kill_{label}"), &par, |o| {
            with_faults(o, "panic:worker@step=4");
        });
        let victim = fleet.tenant("victim").unwrap();
        assert!(!victim.completed(), "{label}: unguarded panic must kill the tenant");
        let err = victim.error.as_deref().unwrap();
        assert!(err.contains("panic"), "{label}: verdict names the panic, got {err:?}");
    }
}

/// NaN-seed one tenant: a guarded NaN-weight fault forces a checkpoint
/// rewind *inside* the victim's slice. The victim survives (one rewind,
/// finite loss, full trajectory) and the neighbors never notice.
#[test]
fn fleet_contains_a_guarded_nan_seed() {
    for (label, par) in fleet_sweep() {
        let fleet = fleet_with_victim(&format!("mr_nan_{label}"), &par, |o| {
            guarded(o);
            o.ckpt_every = 2;
            with_faults(o, "nan:weight@step=3");
        });
        let victim = fleet.tenant("victim").unwrap();
        assert!(victim.completed(), "{label}: guard must absorb the NaN: {:?}", victim.error);
        let out = victim.outcome.as_ref().unwrap();
        assert_eq!(count(out, GuardAction::Rewind), 1, "{label}: one rewind");
        assert!(out.final_train_loss.is_finite(), "{label}: finite after recovery");
        assert_eq!(out.records.len(), 6, "{label}: full trajectory");
    }
}

/// Torn-save one tenant: every suspension checkpoint the victim writes
/// is torn (`torn-save@ckpt=1` with no cadence saves), so each slice
/// auto-resumes into a fresh start — yet completed steps still grow
/// once per slice, the stall backstop never trips, and the victim's
/// final (from-scratch) trajectory equals a clean solo run bitwise.
#[test]
fn fleet_survives_a_torn_save_tenant() {
    for (label, par) in fleet_sweep() {
        let fleet = fleet_with_victim(&format!("mr_torn_{label}"), &par, |o| {
            with_faults(o, "torn-save@ckpt=1");
        });
        let victim = fleet.tenant("victim").unwrap();
        assert!(victim.completed(), "{label}: torn saves must not kill: {:?}", victim.error);
        let out = victim.outcome.as_ref().unwrap();
        assert_eq!(out.records.len(), 6, "{label}: full trajectory despite restarts");
        let root = tmpdir(&format!("mr_torn_solo_{label}"));
        let solo = run_in(&root, ARTIFACT, 6, &par, |_| {}).unwrap();
        assert_outcomes_bitwise_eq(out, &solo, &format!("{label}/victim"));
        std::fs::remove_dir_all(root).ok();
    }
}

/// `--ckpt-keep K` retains only the newest K ring entries.
#[test]
fn ckpt_keep_prunes_the_ring() {
    let dir = tmpdir("keep");
    run_in(&dir, ARTIFACT, 6, &Parallelism::serial(), |o| {
        o.ckpt_every = 1;
        o.ckpt_keep = 2;
    })
    .unwrap();
    let ring = scan_ring(&dir, ARTIFACT);
    let steps: Vec<u64> = ring.iter().map(|(s, _)| *s).collect();
    assert_eq!(steps, [6, 5], "only the newest two checkpoints survive");
    std::fs::remove_dir_all(dir).ok();
}
