//! Trainer smoke tests on the host execution backend: a few
//! `coordinator::Trainer` steps end-to-end on `data/synthetic` streams,
//! **no Python artifacts required**. The PJRT integration tests
//! (`integration_train.rs`) remain the artifact-gated deep coverage;
//! this suite is the tier-1 floor that always runs.

use mor::coordinator::checkpoint::Checkpoint;
use mor::coordinator::trainer::{Trainer, TrainerOptions};
use mor::data::loader::BatchLoader;
use mor::data::synthetic::CorpusProfile;
use mor::model::config::{ModelConfig, TrainConfig};
use mor::model::naming::{param_specs, QuantTensorId};
use mor::runtime::Runtime;
use mor::util::par::Parallelism;

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("mor_smoke_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn trainer_runs_end_to_end_on_host_backend() {
    let rt = Runtime::host(ModelConfig::TINY);
    let out_dir = tmpdir("trainer");
    let trainer = Trainer::new(&rt, TrainConfig::config1(6));
    let mut opts = TrainerOptions::new("train_mor_tensor_block", 6, out_dir.clone());
    opts.val_every = 3;
    opts.suite_every = 0; // suite covered separately; keep the smoke fast
    opts.ckpt_every = 4;
    opts.quiet = true;
    opts.parallelism = Some(Parallelism::auto());
    let outcome = trainer.run(&opts).unwrap();

    assert_eq!(outcome.records.len(), 6);
    assert!(outcome.final_train_loss.is_finite(), "loss {}", outcome.final_train_loss);
    assert!(outcome.final_val_loss.is_finite(), "val loss {}", outcome.final_val_loss);
    assert!(outcome.metrics_path.exists());
    // The BF16-fallback percentage is populated (0..=100, and the MoR
    // recipe recorded per-tensor decisions for every step).
    let fb = outcome.stats.overall_fallback_pct();
    assert!((0.0..=100.0).contains(&fb), "fallback pct {fb}");
    assert!(!outcome.stats.tensors().is_empty(), "no per-tensor stats recorded");
    assert!(
        outcome.records.iter().all(|r| (0.0..=1.0).contains(&r.bf16_fallback_rate)),
        "fallback rates out of range"
    );
    assert!(
        outcome.records.iter().any(|r| r.mean_relerr > 0.0),
        "relerr telemetry never populated"
    );

    // Checkpoint written after 4 completed steps and loadable with the
    // right arity; it is a full MORCKPT2 training checkpoint (state
    // sections present), and the final step checkpoints too.
    let ckpt_path = out_dir.join("train_mor_tensor_block.step4.ckpt");
    assert!(ckpt_path.exists(), "checkpoint not written");
    let ck = Checkpoint::load(&ckpt_path).unwrap();
    assert_eq!(ck.step, 4);
    assert_eq!(ck.tensors.len(), param_specs(&ModelConfig::TINY).len());
    for sect in ["opt/m", "opt/v", "data/train", "data/val", "rng/streams", "mor/stats"] {
        assert!(ck.section(sect).is_some(), "missing checkpoint section {sect}");
    }
    assert!(out_dir.join("train_mor_tensor_block.step6.ckpt").exists());
    std::fs::remove_dir_all(out_dir).ok();
}

/// One line per step: `step,train_loss_bits,fallback_bits,relerr_bits`
/// (f32 bit patterns in hex — the bitwise trajectory).
fn run_trajectory() -> Vec<String> {
    let rt = Runtime::host(ModelConfig::TINY);
    let out_dir = tmpdir("golden_traj");
    let trainer = Trainer::new(&rt, TrainConfig::config1(6));
    let mut opts = TrainerOptions::new("train_mor_tensor_block", 6, out_dir.clone());
    opts.val_every = 0; // loss + repr-type fractions only: minimal golden
    opts.suite_every = 0;
    opts.quiet = true;
    opts.parallelism = Some(Parallelism::auto());
    let outcome = trainer.run(&opts).unwrap();
    std::fs::remove_dir_all(out_dir).ok();
    outcome
        .records
        .iter()
        .map(|r| {
            format!(
                "{},{:08x},{:08x},{:08x}",
                r.step,
                r.train_loss.to_bits(),
                r.bf16_fallback_rate.to_bits(),
                r.mean_relerr.to_bits()
            )
        })
        .collect()
}

/// The strict cross-checkout golden pin is scoped to the platform CI
/// runs on: the trajectory passes through libm transcendentals
/// (exp/ln in the loss softmax, powf in Adam bias correction), whose
/// last-ulp results can differ across libms/architectures. Elsewhere
/// the run-twice determinism check still applies, without the
/// bit-pattern comparison against a Linux-generated file.
const GOLDEN_PINNED_PLATFORM: bool = cfg!(all(target_os = "linux", target_arch = "x86_64"));

/// Golden-trajectory regression: the committed host-backend trajectory
/// (loss + repr-type fractions for the trainer_smoke config) must be
/// reproduced **exactly** — future PRs cannot silently change the
/// numerics. Because the parallel ≡ serial contract is bitwise, the
/// same golden holds at every `MOR_THREADS` the CI matrix pins.
///
/// Bootstrap: if the golden file does not exist yet (fresh clone of a
/// branch that predates it, or regeneration after an *intentional*
/// numerics change — delete the file), the test verifies the
/// trajectory is self-reproducible, writes the file, and passes;
/// commit the generated file to pin it.
#[test]
fn golden_trajectory_reproduced_exactly() {
    let lines = run_trajectory();
    assert_eq!(lines.len(), 6);
    let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden/trainer_smoke_trajectory.csv");
    if !GOLDEN_PINNED_PLATFORM {
        // Off the pinned platform: prove run-to-run determinism only.
        let again = run_trajectory();
        assert_eq!(lines, again, "trajectory not deterministic across fresh runs");
        eprintln!("golden pin skipped (not the pinned linux/x86_64 platform)");
        return;
    }
    match std::fs::read_to_string(&path) {
        Ok(text) => {
            let want: Vec<&str> =
                text.lines().filter(|l| !l.starts_with('#') && !l.is_empty()).collect();
            assert_eq!(
                want.len(),
                lines.len(),
                "golden {} has {} rows, trajectory has {}",
                path.display(),
                want.len(),
                lines.len()
            );
            for (i, (got, want)) in lines.iter().zip(want.iter()).enumerate() {
                assert_eq!(
                    got, want,
                    "trajectory diverged from {} at step {i} \
                     (numerics changed — if intentional, delete the golden and re-run)",
                    path.display()
                );
            }
        }
        Err(_) => {
            // No committed golden yet: prove determinism (two fresh
            // end-to-end runs agree bitwise), then bootstrap the file.
            let again = run_trajectory();
            assert_eq!(lines, again, "trajectory not deterministic across fresh runs");
            let mut text = String::from(
                "# step,train_loss_bits,bf16_fallback_rate_bits,mean_relerr_bits (f32 hex)\n\
                 # trainer_smoke config: TINY / train_mor_tensor_block / config1(6), 6 steps\n\
                 # Pinned platform: linux/x86_64 (libm last-ulp sensitivity); other\n\
                 # platforms run the determinism check only.\n\
                 # Bootstrapped by golden_trajectory_reproduced_exactly — commit this file.\n",
            );
            for l in &lines {
                text.push_str(l);
                text.push('\n');
            }
            // Best-effort: a read-only checkout still gets the
            // run-twice determinism check above.
            match std::fs::write(&path, text) {
                Ok(()) => eprintln!("bootstrapped golden trajectory at {}", path.display()),
                Err(e) => eprintln!("could not write golden trajectory: {e}"),
            }
        }
    }
}

/// The legacy embedded-metrics checkpoint representation (the
/// `--embed-metrics` flag) still resumes bitwise — both `MetricsState`
/// codec paths are exercised (the digest default is covered by
/// `resume_equals_continuous_bitwise` in parallel_equivalence.rs).
#[test]
fn embedded_metrics_checkpoints_still_resume_bitwise() {
    const ARTIFACT: &str = "train_mor_tensor_block";
    let rt = Runtime::host(ModelConfig::TINY);
    let trainer = Trainer::new(&rt, TrainConfig::config1(4));
    let base = tmpdir("embed_resume");
    let mk = |out: std::path::PathBuf, resume: Option<std::path::PathBuf>| {
        let mut o = TrainerOptions::new(ARTIFACT, 4, out);
        o.val_every = 2;
        o.ckpt_every = 2;
        o.embed_metrics = true;
        o.quiet = true;
        o.resume = resume;
        o.parallelism = Some(Parallelism::auto());
        o
    };
    let cont = trainer.run(&mk(base.join("cont"), None)).unwrap();
    let ckpt = base.join("cont").join(format!("{ARTIFACT}.step2.ckpt"));
    assert!(ckpt.exists(), "embedded-mode checkpoint missing");
    // The embedded representation really is in the file (not a digest).
    let ck = mor::coordinator::checkpoint::TrainCheckpoint::load(&ckpt).unwrap();
    assert!(ck.metrics.embedded().is_some(), "embed_metrics must embed the rows");
    assert_eq!(ck.metrics.rows(), 2);
    let res = trainer.run(&mk(base.join("res"), Some(ckpt))).unwrap();
    assert_eq!(cont.records.len(), res.records.len());
    for (a, b) in cont.records.iter().zip(res.records.iter()) {
        assert_eq!(a.step, b.step);
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "step {}", a.step);
        assert_eq!(a.val_loss.to_bits(), b.val_loss.to_bits(), "step {}", a.step);
        assert_eq!(a.param_norm.to_bits(), b.param_norm.to_bits(), "step {}", a.step);
    }
    std::fs::remove_dir_all(base).ok();
}

#[test]
fn host_baseline_loss_decreases() {
    let rt = Runtime::host(ModelConfig::TINY);
    let mut s = rt.train_session("train_baseline", 42).unwrap();
    let loader = BatchLoader::new(CorpusProfile::Nemotron4Like, 256, s.batch, s.seq, 42, 0);
    let mut first = 0f32;
    let mut last = 0f32;
    for i in 0..12 {
        let b = loader.next_batch();
        let out = s.step(&b.tokens, 3e-3, 0.045).unwrap();
        assert!(out.loss.is_finite(), "step {i} loss {}", out.loss);
        if i == 0 {
            first = out.loss;
        }
        last = out.loss;
    }
    assert!(
        last < first - 0.1,
        "loss should drop over 12 host steps: first {first}, last {last}"
    );
    assert_eq!(s.stats_len, QuantTensorId::count(&ModelConfig::TINY));
}

#[test]
fn host_mor_recipes_populate_fallback() {
    let rt = Runtime::host(ModelConfig::TINY);
    // Tensor-level: fallback is 0/1 per slot. Sub-tensor: fractional.
    let mut tl = rt.train_session("train_mor_tensor_block", 7).unwrap();
    let loader = BatchLoader::new(CorpusProfile::NemotronHLike, 256, tl.batch, tl.seq, 7, 0);
    let b = loader.next_batch();
    let out = tl.step(&b.tokens, 1e-3, 0.045).unwrap();
    assert_eq!(out.relerr.len(), QuantTensorId::count(&ModelConfig::TINY));
    for (re, fb) in out.relerr.iter().zip(out.fallback.iter()) {
        assert!((0.0..1.0).contains(re), "relerr {re}");
        assert!(*fb == 0.0 || *fb == 1.0, "tensor-level fallback must be 0/1, got {fb}");
    }
    assert!(out.relerr.iter().any(|r| *r > 0.0));

    let mut st = rt.train_session("train_mor_subtensor_two_way", 7).unwrap();
    let out = st.step(&b.tokens, 1e-3, 0.045).unwrap();
    for fb in &out.fallback {
        assert!((0.0..=1.0).contains(fb), "sub-tensor fallback {fb}");
    }
}

#[test]
fn host_training_is_deterministic_given_seed() {
    let rt = Runtime::host(ModelConfig::TINY);
    let run = |seed: u64| -> Vec<f32> {
        let mut s = rt.train_session("train_baseline", seed).unwrap();
        let loader = BatchLoader::new(CorpusProfile::Nemotron4Like, 256, s.batch, s.seq, seed, 0);
        (0..3)
            .map(|_| s.step(&loader.next_batch().tokens, 1e-3, 0.045).unwrap().loss)
            .collect()
    };
    assert_eq!(run(5), run(5));
    assert_ne!(run(5), run(6));
}

#[test]
fn host_eval_session_scores_suite() {
    use mor::coordinator::eval::eval_suite;
    use mor::coordinator::trainer::full_mask;
    use mor::data::tasks::EvalSuite;

    let rt = Runtime::host(ModelConfig::TINY);
    let mut s = rt.train_session("train_baseline", 3).unwrap();
    let ev = rt.eval_session("eval").unwrap();
    let loader = BatchLoader::new(CorpusProfile::Nemotron4Like, 256, ev.batch, ev.seq, 3, 1);
    let b = loader.next_batch();
    let mask = full_mask(ev.batch, ev.seq);
    // Tensor-native path (zero-copy on the host backend)...
    let (loss, acc) = ev.eval_params(s.params_ref(), &b.tokens, &mask).unwrap();
    assert!(loss > 0.0 && loss.is_finite());
    // Untrained model ≈ chance accuracy over 256 symbols.
    assert!(acc < 0.05, "untrained acc {acc}");
    // ...agrees bitwise with the Literal-interchange path.
    let (loss_lit, acc_lit) = ev.eval(s.param_literals(), &b.tokens, &mask).unwrap();
    assert_eq!(loss.to_bits(), loss_lit.to_bits());
    assert_eq!(acc.to_bits(), acc_lit.to_bits());

    let suite = EvalSuite::new(ev.seq, 256, 2, 99);
    let scores = eval_suite(&ev, s.params_ref(), &suite).unwrap();
    assert_eq!(scores.per_task.len(), 5);
    for (name, loss, acc) in &scores.per_task {
        assert!(loss.is_finite(), "{name}");
        assert!((0.0..=100.0).contains(acc), "{name} acc {acc}");
    }
}
