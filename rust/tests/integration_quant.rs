//! Cross-language equivalence: the compiled HLO quant kernels (Pallas →
//! XLA → PJRT) against the bit-exact Rust host mirror, on identical
//! inputs. This is the proof that the three implementations of the
//! paper's numerics — Pallas kernel, jnp oracle, Rust engine — agree.
//!
//! Requires `make artifacts-tiny` (artifacts/tiny). Tests self-skip if
//! artifacts are missing so `cargo test` stays runnable pre-build.

use mor::formats::ReprType;
use mor::model::config::ModelConfig;
use mor::quant::fake_quant::fake_quantize;
use mor::quant::partition::Partition;
use mor::runtime::Runtime;
use mor::scaling::ScalingAlgo;
use mor::tensor::Tensor;
use std::path::Path;

fn runtime() -> Option<Runtime> {
    let dir = Path::new("artifacts/tiny");
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: artifacts/tiny not built (run `make artifacts-tiny`)");
        return None;
    }
    Some(Runtime::load(dir, ModelConfig::TINY).expect("loading tiny artifacts"))
}

/// The quant artifacts are all 256x256 — matches aot.py QUANT_ROWS/COLS.
fn test_tensor(seed: u64, spread: bool) -> Tensor {
    let mut t = Tensor::normal(&[256, 256], 2.0, seed);
    if spread {
        for (i, v) in t.data_mut().iter_mut().enumerate() {
            *v *= (10.0f32).powi((i % 9) as i32 - 4);
        }
    }
    t
}

fn check_artifact(
    rt: &Runtime,
    name: &str,
    fmt: ReprType,
    partition: Partition,
    scaling: ScalingAlgo,
) {
    let session = rt.quant_session(name).expect(name);
    for (seed, spread) in [(1u64, false), (2, true), (3, false)] {
        let x = test_tensor(seed, spread);
        let (hlo_out, hlo_relerr) = session.run(&x).expect("executing quant artifact");
        let host = fake_quantize(&x, fmt, partition, scaling);

        // Element-wise equivalence between PJRT-compiled Pallas and the
        // Rust mirror. The Rust mirror is bit-exact against *eager*
        // JAX (pinned by the ml_dtypes goldens below and pytest); the
        // AOT-compiled XLA CPU binary additionally FMA-contracts the
        // scale multiply, which flips values sitting exactly on an RNE
        // tie to the adjacent fp8 grid point. Bound: < 1% of elements,
        // each within one grid step (~12.5% relative for fp8).
        let mut mismatches = 0usize;
        let amax = x.amax();
        for (a, b) in hlo_out.data().iter().zip(host.out.data()) {
            let d = (a - b).abs();
            if d != 0.0 {
                mismatches += 1;
                // Adjacent normal-range codes differ by <= 2^-2 rel;
                // subnormal-range codes can differ by more relative but
                // are tiny against the tensor's magnitude envelope.
                let rel = d / a.abs().max(b.abs()).max(1e-30);
                assert!(
                    rel < 0.26 || d < 2e-3 * amax,
                    "{name} seed {seed}: non-adjacent mismatch {a} vs {b} (input amax {amax})"
                );
            }
        }
        assert!(
            (mismatches as f64) < 0.01 * hlo_out.len() as f64,
            "{name} seed {seed}: {mismatches}/{} mismatching elements",
            hlo_out.len()
        );

        // Relative-error metric agreement (f32 vs f64 accumulation).
        let host_relerr = host.global_err.mean() as f32;
        assert!(
            (hlo_relerr - host_relerr).abs() < 1e-4 + host_relerr * 1e-3,
            "{name} seed {seed}: relerr {hlo_relerr} vs host {host_relerr}"
        );
    }
}

#[test]
fn quant_e4m3_gam_block128_matches_host() {
    let Some(rt) = runtime() else { return };
    check_artifact(
        &rt,
        "quant_e4m3_gam_block128",
        ReprType::E4M3,
        Partition::BLOCK128,
        ScalingAlgo::Gam,
    );
}

#[test]
fn quant_e4m3_gam_block64_matches_host() {
    let Some(rt) = runtime() else { return };
    check_artifact(
        &rt,
        "quant_e4m3_gam_block64",
        ReprType::E4M3,
        Partition::BLOCK64,
        ScalingAlgo::Gam,
    );
}

#[test]
fn quant_e4m3_gam_tensor_matches_host() {
    let Some(rt) = runtime() else { return };
    check_artifact(
        &rt,
        "quant_e4m3_gam_tensor",
        ReprType::E4M3,
        Partition::Tensor,
        ScalingAlgo::Gam,
    );
}

#[test]
fn quant_e4m3_gam_channel_rows_matches_host() {
    let Some(rt) = runtime() else { return };
    check_artifact(
        &rt,
        "quant_e4m3_gam_channel_rows",
        ReprType::E4M3,
        Partition::ChannelRows,
        ScalingAlgo::Gam,
    );
}

#[test]
fn quant_e4m3_gam_channel_cols_matches_host() {
    let Some(rt) = runtime() else { return };
    check_artifact(
        &rt,
        "quant_e4m3_gam_channel_cols",
        ReprType::E4M3,
        Partition::ChannelCols,
        ScalingAlgo::Gam,
    );
}

#[test]
fn quant_e4m3_amax_block128_matches_host() {
    let Some(rt) = runtime() else { return };
    check_artifact(
        &rt,
        "quant_e4m3_amax_block128",
        ReprType::E4M3,
        Partition::BLOCK128,
        ScalingAlgo::AmaxFp32,
    );
}

#[test]
fn quant_e4m3_e8m0_block128_matches_host() {
    let Some(rt) = runtime() else { return };
    check_artifact(
        &rt,
        "quant_e4m3_e8m0_block128",
        ReprType::E4M3,
        Partition::BLOCK128,
        ScalingAlgo::E8M0,
    );
}

#[test]
fn quant_e5m2_gam_block128_matches_host() {
    let Some(rt) = runtime() else { return };
    check_artifact(
        &rt,
        "quant_e5m2_gam_block128",
        ReprType::E5M2,
        Partition::BLOCK128,
        ScalingAlgo::Gam,
    );
}

#[test]
fn quant_artifact_zero_tensor() {
    let Some(rt) = runtime() else { return };
    let s = rt.quant_session("quant_e4m3_gam_block128").unwrap();
    let x = Tensor::zeros(&[256, 256]);
    let (out, relerr) = s.run(&x).unwrap();
    assert!(out.data().iter().all(|v| *v == 0.0));
    assert_eq!(relerr, 0.0);
}

/// Golden cross-check: our fp8 encoders vs `ml_dtypes` (the converter
/// JAX uses), over 8000 random values including subnormal-range and
/// overflow cases. These run without artifacts.
#[test]
fn fp8_e4m3_encode_matches_ml_dtypes_golden() {
    use mor::formats::fp8::{Fp8Format, E4M3};
    // cargo runs integration tests from the package root (rust/).
    let text = std::fs::read_to_string("tests/golden/fp8_e4m3_golden.txt").unwrap();
    let mut checked = 0;
    for line in text.lines() {
        let (v, e) = line.split_once(' ').unwrap();
        let bits = u32::from_str_radix(v, 16).unwrap();
        let expect = u8::from_str_radix(e, 16).unwrap();
        let got = E4M3::encode(f32::from_bits(bits));
        let x = f32::from_bits(bits);
        let (gd, ed) = (E4M3::decode(got), E4M3::decode(expect));
        assert!(
            got == expect || (gd.is_nan() && ed.is_nan()),
            "x={x} ({bits:08x}): ours {got:02x} ({gd}) vs ml_dtypes {expect:02x} ({ed})"
        );
        checked += 1;
    }
    assert_eq!(checked, 8000);
}

#[test]
fn fp8_e5m2_encode_matches_ml_dtypes_golden() {
    use mor::formats::fp8::{Fp8Format, E5M2};
    let text = std::fs::read_to_string("tests/golden/fp8_e5m2_golden.txt").unwrap();
    for line in text.lines() {
        let (v, e) = line.split_once(' ').unwrap();
        let bits = u32::from_str_radix(v, 16).unwrap();
        let expect = u8::from_str_radix(e, 16).unwrap();
        let got = E5M2::encode(f32::from_bits(bits));
        let (gd, ed) = (E5M2::decode(got), E5M2::decode(expect));
        assert!(
            got == expect || (gd.is_nan() && ed.is_nan()),
            "x={} ({bits:08x}): ours {got:02x} ({gd}) vs ml_dtypes {expect:02x} ({ed})",
            f32::from_bits(bits)
        );
    }
}
