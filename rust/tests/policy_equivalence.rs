//! The `DecisionPolicy` refactor's correctness contract:
//!
//! * the default policy path (no `--policy`, `TrainerOptions::policy`
//!   = None) is **bitwise identical** to an explicit
//!   `MorThresholdPolicy` — at 1, 2 and 13 threads — so extracting the
//!   decisions behind the trait changed nothing;
//! * the rival policies (`metric=`, `static=`) keep the parallel ≡
//!   serial contract: any thread count reproduces the serial run
//!   bitwise;
//! * per-policy decision fractions on a fixed adversarial tensor are
//!   pinned against a committed golden
//!   (`tests/golden/policy_decision_fractions.csv`, bootstrapped on
//!   first run like the trainer-smoke trajectory);
//! * `parse_policy` stays strict: malformed specs are loud errors.

use mor::coordinator::trainer::{Trainer, TrainerOptions, TrainOutcome};
use mor::model::config::{ModelConfig, TrainConfig};
use mor::mor::policy::{self, MorThresholdPolicy, PolicyRef};
use mor::mor::recipes::{ApplyCtx, Recipe, RecipeKind, SubTensorMode};
use mor::quant::partition::Partition;
use mor::runtime::Runtime;
use mor::scaling::ScalingAlgo;
use mor::tensor::Tensor;
use mor::util::par::Parallelism;
use std::path::PathBuf;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("mor_poleq_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// A short host training run under an explicit policy (None = inherit
/// the runtime/process default) at an explicit thread count.
fn run_with(tag: &str, spec: Option<&str>, par: Parallelism) -> TrainOutcome {
    let policy: Option<PolicyRef> = spec.map(|s| {
        policy::parse_policy(Some(s)).expect("valid spec").expect("non-empty spec")
    });
    let rt = Runtime::host(ModelConfig::TINY);
    let out_dir = tmpdir(tag);
    let trainer = Trainer::new(&rt, TrainConfig::config1(2));
    let mut opts = TrainerOptions::new("train_mor_subtensor_three_way", 2, out_dir.clone());
    opts.val_every = 1;
    opts.quiet = true;
    opts.parallelism = Some(par);
    opts.policy = policy;
    let outcome = trainer.run(&opts).unwrap();
    std::fs::remove_dir_all(out_dir).ok();
    outcome
}

fn assert_outcomes_bitwise_eq(a: &TrainOutcome, b: &TrainOutcome, what: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{what}: record count");
    for (ra, rb) in a.records.iter().zip(b.records.iter()) {
        assert_eq!(ra.step, rb.step, "{what}");
        assert_eq!(
            ra.train_loss.to_bits(),
            rb.train_loss.to_bits(),
            "{what}: train loss at step {}",
            ra.step
        );
        assert_eq!(
            ra.val_loss.to_bits(),
            rb.val_loss.to_bits(),
            "{what}: val loss at step {}",
            ra.step
        );
        assert_eq!(
            ra.bf16_fallback_rate.to_bits(),
            rb.bf16_fallback_rate.to_bits(),
            "{what}: fallback at step {}",
            ra.step
        );
        assert_eq!(
            ra.mean_relerr.to_bits(),
            rb.mean_relerr.to_bits(),
            "{what}: relerr at step {}",
            ra.step
        );
        assert_eq!(
            ra.param_norm.to_bits(),
            rb.param_norm.to_bits(),
            "{what}: param norm at step {}",
            ra.step
        );
    }
}

/// The refactor's central claim: routing every decision through the
/// `DecisionPolicy` trait with the default `MorThresholdPolicy` is a
/// pure refactor — the no-policy path and the explicit-threshold path
/// produce bit-identical trajectories at any thread count.
#[test]
fn default_equals_explicit_threshold_bitwise_at_any_thread_count() {
    for (label, par) in [
        ("serial", Parallelism::serial()),
        ("pooled2", Parallelism::pooled(2, 1)),
        ("pooled13", Parallelism::pooled(13, 1)),
    ] {
        let implicit = run_with(&format!("def_{label}"), None, par.clone());
        let explicit = run_with(&format!("thr_{label}"), Some("threshold"), par);
        assert_outcomes_bitwise_eq(&implicit, &explicit, label);
    }
}

/// The rival policies inherit the engine's parallel ≡ serial contract:
/// nothing in `MetricDrivenPolicy`/`StaticAssignmentPolicy` depends on
/// scheduling order.
#[test]
fn rival_policies_parallel_equals_serial_bitwise() {
    for spec in ["metric=0.03", "static=e4m3,e4m3,e5m2"] {
        let tag = spec.split(['=', ',']).next().unwrap();
        let serial = run_with(&format!("{tag}_s"), Some(spec), Parallelism::serial());
        let pooled =
            run_with(&format!("{tag}_p"), Some(spec), Parallelism::pooled(13, 1));
        assert_outcomes_bitwise_eq(&serial, &pooled, spec);
    }
}

/// A wide-dynamic-range tensor (seven decades of magnitude inside
/// every 128-block): forces non-trivial decisions out of every policy.
fn wild_tensor() -> Tensor {
    let base = Tensor::normal(&[128, 128], 3.0, 11);
    let data: Vec<f32> =
        base.data().iter().enumerate().map(|(i, v)| v * 10f32.powi((i % 7) as i32 - 3)).collect();
    Tensor::from_vec(&[128, 128], data)
}

/// The rival policies genuinely decide differently from threshold —
/// otherwise the comparison harness compares nothing. On the wild
/// tensor: the E4M3 candidate's relerr blows past both the run
/// threshold and the metric budget (sub-amax decades flush to zero),
/// so tensor-level threshold falls back while static never does, and
/// on the three-way recipe threshold's M2 range check admits E5M2
/// while the absolute metric budget rejects it.
#[test]
fn policies_make_distinct_decisions() {
    let par = Parallelism::serial();
    let x = wild_tensor();
    let pol = |s: &str| policy::parse_policy(Some(s)).unwrap().unwrap();
    let apply = |kind: RecipeKind, p: &PolicyRef| {
        let recipe = Recipe { kind, partition: Partition::BLOCK128, scaling: ScalingAlgo::Gam };
        recipe.apply_ctx(&x, &ApplyCtx::new(&par, p.as_ref()))
    };

    let tl = RecipeKind::TensorLevel { threshold: 0.045 };
    let thr_tl = apply(tl, &pol("threshold"));
    let sta_tl = apply(tl, &pol("static=e4m3,e4m3,e5m2"));
    assert!(thr_tl.full_fallback(), "threshold should reject E4M3 on the wild tensor");
    assert_eq!(sta_tl.bf16_fraction, 0.0, "static e4m3 never falls back");

    let s3 = RecipeKind::SubTensor { mode: SubTensorMode::ThreeWay };
    let thr_s3 = apply(s3, &pol("threshold"));
    let met_s3 = apply(s3, &pol("metric=0.03"));
    assert_ne!(
        thr_s3.bf16_fraction.to_bits(),
        met_s3.bf16_fraction.to_bits(),
        "metric budget and threshold M1/M2 should disagree on the wild tensor"
    );
}

// ---------------------------------------------------------------------------
// Golden decision fractions
// ---------------------------------------------------------------------------

/// See `trainer_smoke.rs`: the strict golden pin is scoped to the CI
/// platform; elsewhere the run-twice determinism check still applies.
const GOLDEN_PINNED_PLATFORM: bool = cfg!(all(target_os = "linux", target_arch = "x86_64"));

/// One line per (policy, recipe):
/// `policy,recipe,bf16_fraction_bits,e4m3_relerr_bits` (f64 bit
/// patterns in hex) for the fixed wide-dynamic-range tensor.
fn decision_fraction_lines() -> Vec<String> {
    let par = Parallelism::serial();
    let x = wild_tensor();

    let mut lines = Vec::new();
    for spec in ["threshold", "metric=0.03", "static=e4m3,e4m3,e5m2"] {
        let pol = policy::parse_policy(Some(spec)).unwrap().unwrap();
        let ctx = ApplyCtx::new(&par, pol.as_ref());
        for (rname, kind) in [
            ("tensor_level", RecipeKind::TensorLevel { threshold: 0.045 }),
            ("subtensor2", RecipeKind::SubTensor { mode: SubTensorMode::TwoWay }),
            ("subtensor3", RecipeKind::SubTensor { mode: SubTensorMode::ThreeWay }),
        ] {
            let recipe =
                Recipe { kind, partition: Partition::BLOCK128, scaling: ScalingAlgo::Gam };
            let o = recipe.apply_ctx(&x, &ctx);
            lines.push(format!(
                "{spec},{rname},{:016x},{:016x}",
                o.bf16_fraction.to_bits(),
                o.e4m3_relerr.to_bits()
            ));
        }
    }
    lines
}

/// Decision-fraction golden: per-policy fallback fractions on a fixed
/// tensor are pinned, so a change to any policy's decision logic (or
/// to the shared plan walk) cannot land silently. Bootstrap mirrors
/// `golden_trajectory_reproduced_exactly`.
#[test]
fn golden_decision_fractions_reproduced_exactly() {
    let lines = decision_fraction_lines();
    assert_eq!(lines.len(), 9, "3 policies x 3 recipes");
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden/policy_decision_fractions.csv");
    if !GOLDEN_PINNED_PLATFORM {
        let again = decision_fraction_lines();
        assert_eq!(lines, again, "decision fractions not deterministic across runs");
        eprintln!("golden pin skipped (not the pinned linux/x86_64 platform)");
        return;
    }
    match std::fs::read_to_string(&path) {
        Ok(text) => {
            let want: Vec<&str> =
                text.lines().filter(|l| !l.starts_with('#') && !l.is_empty()).collect();
            assert_eq!(want.len(), lines.len(), "golden {} row count", path.display());
            for (got, want) in lines.iter().zip(want.iter()) {
                assert_eq!(
                    got, want,
                    "decision fractions diverged from {} \
                     (policy logic changed — if intentional, delete the golden and re-run)",
                    path.display()
                );
            }
        }
        Err(_) => {
            let again = decision_fraction_lines();
            assert_eq!(lines, again, "decision fractions not deterministic across runs");
            let mut text = String::from(
                "# policy,recipe,bf16_fraction_bits,e4m3_relerr_bits (f64 hex)\n\
                 # Fixed 128x128 wide-dynamic-range tensor, BLOCK128/Gam, serial.\n\
                 # Pinned platform: linux/x86_64; other platforms run the\n\
                 # determinism check only.\n\
                 # Bootstrapped by golden_decision_fractions_reproduced_exactly — commit this file.\n",
            );
            for l in &lines {
                text.push_str(l);
                text.push('\n');
            }
            match std::fs::write(&path, text) {
                Ok(()) => eprintln!("bootstrapped decision-fraction golden at {}", path.display()),
                Err(e) => eprintln!("could not write decision-fraction golden: {e}"),
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Spec parsing stays strict
// ---------------------------------------------------------------------------

#[test]
fn parse_policy_accepts_the_documented_grammar() {
    assert!(policy::parse_policy(None).unwrap().is_none());
    for (spec, describe) in [
        ("threshold", "threshold"),
        ("metric", "metric=0.03"),
        ("metric=0.03", "metric=0.03"),
        (" metric = 0.03 ", "metric=0.03"),
        ("static=e4m3,e4m3,e5m2", "static=e4m3,e4m3,e5m2"),
    ] {
        let p = policy::parse_policy(Some(spec)).unwrap_or_else(|e| panic!("{spec:?}: {e}"));
        assert_eq!(p.expect("some policy").describe(), describe, "{spec:?}");
    }
    // Pins are stable identities: same spec → same pin, different
    // configuration → different pin.
    let pin = |s: &str| policy::parse_policy(Some(s)).unwrap().unwrap().pin();
    assert_eq!(pin("threshold"), MorThresholdPolicy.pin());
    assert_eq!(pin("metric=0.03"), pin("metric=0.03"));
    assert_ne!(pin("metric=0.03"), pin("metric=0.05"));
    assert_ne!(pin("static=e4m3,e4m3,e5m2"), pin("static=e4m3,e4m3,e4m3"));
}

#[test]
fn parse_policy_rejects_malformed_specs_loudly() {
    for bad in [
        "",
        "  ",
        "nope",
        "threshold=0.5",
        "metric=",
        "metric=-1",
        "metric=nan",
        "metric=0",
        "static=e4m3",
        "static=e4m3,e4m3",
        "static=e4m3,e4m3,int8",
        "static=e4m3,e4m3,e5m2,bf16",
    ] {
        let r = policy::parse_policy(Some(bad));
        assert!(r.is_err(), "spec {bad:?} should be rejected, got {r:?}");
    }
}

/// The `MOR_POLICY` knob is registered (satellite of the same PR that
/// introduced the policies): the README table generator includes it.
#[test]
fn mor_policy_knob_is_registered() {
    let table = mor::util::env::knobs_markdown();
    assert!(table.contains("MOR_POLICY"), "knob table missing MOR_POLICY:\n{table}");
    assert!(table.contains("--policy SPEC"), "knob table missing the CLI twin:\n{table}");
}
