//! The fleet supervisor's proof obligations:
//!
//! * **Supervision is transparent while unneeded.** A fault-free
//!   supervised fleet is bitwise identical to an unsupervised one —
//!   same schedule log, same per-tenant records and final checkpoint
//!   state — at 1, 2 and 13 threads, and every tenant reports Healthy
//!   with zero retries and zero demotions.
//! * **Failure walks a ladder, not a cliff.** A tenant whose slices
//!   keep dying burns its retry budget (with exponential backoff
//!   measured in scheduler rounds), is demoted to the BF16 quarantine
//!   rung, then to scalar kernels, and only then is declared Dead —
//!   while its neighbors stay bitwise identical to their solo runs.
//! * **Backoff is deterministic.** The supervised schedule log and
//!   every terminal report are identical across thread counts: backoff
//!   is counted in rounds, never wall-clock.
//! * **Demotion rescues what retry cannot.** A tenant whose own guard
//!   exhausts its rewind budget is demoted (skipping the futile retry
//!   branch); under the widened guard and BF16 policy it completes,
//!   reporting the sticky Quarantined state.
//! * **The stall watchdog converts silence into a verdict.** A wedged
//!   tenant (the `stall` fault, self-preempting via the cooperative
//!   stop flag) accrues no-progress slices until the watchdog trips
//!   and the ladder runs to its documented terminal state.
//! * **The fleet manifest makes the whole fleet crash-safe.** A fleet
//!   halted mid-run (simulated supervisor crash) auto-resumes from the
//!   manifest bitwise identical to the uninterrupted fleet; a corrupt
//!   manifest degrades to a fresh ledger (tenant rings still resume to
//!   the same final state); a manifest for a different fleet refuses
//!   to resume.

use mor::coordinator::checkpoint::{scan_ring, TrainCheckpoint};
use mor::coordinator::guard::{GuardAction, GuardConfig};
use mor::coordinator::scheduler::{run_fleet, FleetOptions, FleetOutcome, Tenant};
use mor::coordinator::supervisor::{Health, SupervisorOptions};
use mor::coordinator::trainer::{TrainOutcome, Trainer, TrainerOptions};
use mor::faults::parse_faults;
use mor::model::config::{ModelConfig, TrainConfig};
use mor::runtime::Runtime;
use mor::util::par::Parallelism;
use std::path::{Path, PathBuf};
use std::sync::atomic::AtomicBool;
use std::sync::Arc;

const ARTIFACT: &str = "train_mor_tensor_block";

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("mor_sup_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// The acceptance matrix for the supervision contracts.
fn thread_sweep() -> [(&'static str, Parallelism); 3] {
    [
        ("serial", Parallelism::serial()),
        ("pooled2", Parallelism::pooled(2, 1)),
        ("pooled13", Parallelism::pooled(13, 1)),
    ]
}

fn opts_in(dir: &Path, steps: u64, par: &Parallelism) -> TrainerOptions {
    let mut o = TrainerOptions::new(ARTIFACT, steps, dir.to_path_buf());
    o.val_every = 1;
    o.ckpt_every = 2;
    o.quiet = true;
    o.parallelism = Some(par.clone());
    o
}

fn mk_tenant(
    id: &str,
    steps: u64,
    dir: &Path,
    par: &Parallelism,
    tweak: &dyn Fn(&mut TrainerOptions),
) -> Tenant {
    let mut o = opts_in(dir, steps, par);
    tweak(&mut o);
    Tenant::new(id, ModelConfig::TINY, TrainConfig::config1(steps), o)
}

fn solo(dir: &Path, steps: u64, par: &Parallelism) -> TrainOutcome {
    let rt = Runtime::host(ModelConfig::TINY);
    Trainer::new(&rt, TrainConfig::config1(steps))
        .run(&opts_in(dir, steps, par))
        .expect("solo run completes")
}

fn with_faults(o: &mut TrainerOptions, spec: &str) {
    o.faults = parse_faults(Some(spec)).expect("valid fault spec");
}

/// Newest ring entry's timing-free state fingerprint.
fn final_fingerprint(dir: &Path, artifact: &str) -> u64 {
    let (step, path) = scan_ring(dir, artifact)
        .into_iter()
        .next()
        .unwrap_or_else(|| panic!("no checkpoint ring in {}", dir.display()));
    let ck = TrainCheckpoint::load(&path).expect("final checkpoint loads");
    assert_eq!(ck.step, step);
    ck.state_fingerprint()
}

fn assert_outcomes_bitwise_eq(a: &TrainOutcome, b: &TrainOutcome, what: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{what}: record count");
    for (ra, rb) in a.records.iter().zip(b.records.iter()) {
        assert_eq!(ra.step, rb.step, "{what}");
        assert_eq!(
            ra.train_loss.to_bits(),
            rb.train_loss.to_bits(),
            "{what}: train loss at step {}",
            ra.step
        );
        assert_eq!(
            ra.val_loss.to_bits(),
            rb.val_loss.to_bits(),
            "{what}: val loss at step {}",
            ra.step
        );
        assert_eq!(
            ra.bf16_fallback_rate.to_bits(),
            rb.bf16_fallback_rate.to_bits(),
            "{what}: fallback at step {}",
            ra.step
        );
        assert_eq!(
            ra.param_norm.to_bits(),
            rb.param_norm.to_bits(),
            "{what}: param norm at step {}",
            ra.step
        );
    }
    assert_eq!(a.guard_events, b.guard_events, "{what}: guard events");
}

// ---------------------------------------------------------------------------
// Supervised ≡ unsupervised while fault-free
// ---------------------------------------------------------------------------

/// With no failures the supervisor only observes: it never removes a
/// tenant from the candidate set, so stride selection — and therefore
/// the schedule log, every tenant's trajectory, and every final
/// checkpoint — is bitwise identical to an unsupervised fleet, at
/// every thread count. Every tenant ends Healthy with zero retries.
#[test]
fn supervised_fault_free_fleet_matches_unsupervised_bitwise() {
    let nop: &dyn Fn(&mut TrainerOptions) = &|_| {};
    for (label, par) in thread_sweep() {
        let root = tmpdir(&format!("transparent_{label}"));
        let specs: [(&str, u64); 3] = [("a", 6), ("b", 4), ("c", 5)];
        let run = |sub: &str, so: Option<SupervisorOptions>| {
            let tenants: Vec<Tenant> = specs
                .iter()
                .map(|(id, steps)| {
                    mk_tenant(id, *steps, &root.join(sub).join(id), &par, nop)
                })
                .collect();
            let mut fo = FleetOptions::new(par.clone());
            fo.quantum = 2;
            fo.max_runs = 2;
            fo.supervisor = so;
            run_fleet(&tenants, &fo).expect("fleet completes")
        };
        let plain = run("unsup", None);
        let supervised = run("sup", Some(SupervisorOptions::new()));

        assert_eq!(supervised.schedule, plain.schedule, "{label}: schedule log");
        assert_eq!(supervised.rounds, plain.rounds, "{label}: round count");
        for (id, _) in &specs {
            let s = supervised.tenant(id).unwrap();
            let p = plain.tenant(id).unwrap();
            assert!(s.completed(), "{label}/{id}: {:?}", s.error);
            assert_eq!(s.health, Health::Healthy, "{label}/{id}");
            assert_eq!((s.retries, s.demotions), (0, 0), "{label}/{id}");
            assert_outcomes_bitwise_eq(
                s.outcome.as_ref().unwrap(),
                p.outcome.as_ref().unwrap(),
                &format!("{label}/{id}"),
            );
            assert_eq!(
                final_fingerprint(&root.join("sup").join(id), ARTIFACT),
                final_fingerprint(&root.join("unsup").join(id), ARTIFACT),
                "{label}/{id}: final checkpoint state"
            );
        }
        // The cross-tenant summary covers every tenant in both forms.
        let table = supervised.summary_table();
        let csv = supervised.summary_csv();
        for (id, _) in &specs {
            assert!(table.contains(id), "summary table lists {id}");
        }
        assert!(table.contains("healthy"), "summary table shows health");
        assert_eq!(csv.lines().count(), specs.len() + 1, "csv: header + one row each");
        assert!(csv.starts_with("tenant,weight,slices,retries,demotions,health,"));
        std::fs::remove_dir_all(root).ok();
    }
}

// ---------------------------------------------------------------------------
// The failure ladder
// ---------------------------------------------------------------------------

/// A three-tenant fleet whose middle tenant dies every slice (an
/// unguarded injected panic at a step it never gets past). With a
/// one-retry budget the ladder is: retry at rung 0 → demote to BF16
/// quarantine → retry → demote to scalar kernels → retry → Dead.
fn ladder_fleet(root: &Path, par: &Parallelism) -> (Vec<Tenant>, FleetOutcome) {
    let nop: &dyn Fn(&mut TrainerOptions) = &|_| {};
    let tenants = vec![
        mk_tenant("left", 6, &root.join("fleet").join("left"), par, nop),
        mk_tenant("victim", 6, &root.join("fleet").join("victim"), par, &|o| {
            with_faults(o, "panic:worker@step=2");
        }),
        mk_tenant("right", 6, &root.join("fleet").join("right"), par, nop),
    ];
    let mut fo = FleetOptions::new(par.clone());
    fo.quantum = 2;
    fo.max_runs = 2;
    fo.supervisor = Some(SupervisorOptions {
        retries: 1,
        backoff: 1,
        ..SupervisorOptions::new()
    });
    let fleet = run_fleet(&tenants, &fo).expect("fleet itself must not die");
    (tenants, fleet)
}

/// Retry exhaustion walks the whole ladder to Dead — the victim's
/// terminal report documents one failed retry per rung (3 total) and
/// both demotions — and the neighbors sharing the pool finish bitwise
/// identical to their solo runs.
#[test]
fn retry_exhaustion_walks_the_demotion_ladder_to_dead() {
    let par = Parallelism::serial();
    let root = tmpdir("ladder");
    let (_, fleet) = ladder_fleet(&root, &par);

    let victim = fleet.tenant("victim").unwrap();
    assert!(!victim.completed(), "every rung must fail");
    assert_eq!(victim.health, Health::Dead, "terminal health");
    assert_eq!(victim.demotions, 2, "both rungs were tried");
    assert_eq!(victim.retries, 3, "one failed retry per rung");
    let err = victim.error.as_deref().unwrap();
    assert!(err.contains("panic"), "verdict names the panic, got {err:?}");

    for id in ["left", "right"] {
        let report = fleet.tenant(id).unwrap();
        assert!(report.completed(), "{id}: neighbor failed: {:?}", report.error);
        assert_eq!(report.health, Health::Healthy, "{id}");
        let solo_out = solo(&root.join("solo").join(id), 6, &par);
        assert_outcomes_bitwise_eq(report.outcome.as_ref().unwrap(), &solo_out, id);
    }
    std::fs::remove_dir_all(root).ok();
}

/// Backoff is measured in scheduler rounds, so the supervised
/// interleaving around a repeatedly-failing tenant — which rounds ran
/// whom, how many slices each tenant got, the victim's terminal
/// ledger — is identical at 1, 2 and 13 threads.
#[test]
fn supervised_backoff_schedule_is_identical_across_thread_counts() {
    let mut baseline: Option<(Vec<mor::coordinator::scheduler::Slice>, Vec<_>)> = None;
    for (label, par) in thread_sweep() {
        let root = tmpdir(&format!("backoff_{label}"));
        let (_, fleet) = ladder_fleet(&root, &par);
        let reports: Vec<(String, u64, u32, u8, Health, Option<String>)> = fleet
            .tenants
            .iter()
            .map(|t| {
                (t.id.clone(), t.slices, t.retries, t.demotions, t.health, t.error.clone())
            })
            .collect();
        match &baseline {
            None => baseline = Some((fleet.schedule.clone(), reports)),
            Some((sched, reps)) => {
                assert_eq!(&fleet.schedule, sched, "{label}: schedule log");
                assert_eq!(&reports, reps, "{label}: terminal reports");
            }
        }
        std::fs::remove_dir_all(root).ok();
    }
}

/// Guard exhaustion skips the retry branch (re-running the same
/// precision would just burn another rewind budget) and demotes
/// immediately; under the demoted BF16 policy and the widened guard
/// the refiring panic is absorbed and the tenant completes — with the
/// sticky Quarantined state and zero retries on its terminal report.
#[test]
fn guard_exhaustion_demotes_and_demotion_rescues() {
    for (label, par) in thread_sweep() {
        let root = tmpdir(&format!("rescue_{label}"));
        let victim =
            mk_tenant("victim", 6, &root.join("victim"), &par, &|o| {
                o.guard = Some(GuardConfig { max_rewinds: 1, ..GuardConfig::default() });
                with_faults(o, "repeat-panic:worker@step=3,count=3");
            });
        let mut fo = FleetOptions::new(par.clone());
        fo.quantum = 4;
        fo.max_runs = 1;
        fo.supervisor = Some(SupervisorOptions::new());
        let fleet = run_fleet(std::slice::from_ref(&victim), &fo).unwrap();

        let report = fleet.tenant("victim").unwrap();
        assert!(report.completed(), "{label}: demotion must rescue: {:?}", report.error);
        assert_eq!(report.health, Health::Quarantined, "{label}: quarantine is sticky");
        assert_eq!(report.demotions, 1, "{label}: one demotion");
        assert_eq!(report.retries, 0, "{label}: guard exhaustion skips retries");
        let out = report.outcome.as_ref().unwrap();
        assert_eq!(out.records.len(), 6, "{label}: full trajectory");
        assert!(out.final_train_loss.is_finite(), "{label}");
        // The widened guard (rewind budget 1*2+2=4) absorbed the three
        // refires in the demoted slice.
        let rewinds = out
            .guard_events
            .iter()
            .filter(|e| e.action == GuardAction::Rewind)
            .count();
        assert_eq!(rewinds, 3, "{label}: one rewind per surviving refire");
        std::fs::remove_dir_all(root).ok();
    }
}

/// A stalled tenant (the `stall` fault: a deterministic wedge that
/// self-preempts through the cooperative stop flag) keeps getting
/// scheduled but never completes a step. The watchdog converts the
/// silence into ladder failures — and since no rung can unwedge it,
/// the documented terminal state is Dead, at every thread count.
#[test]
fn stall_watchdog_walks_a_wedged_tenant_to_dead() {
    for (label, par) in thread_sweep() {
        let root = tmpdir(&format!("stall_{label}"));
        let victim = mk_tenant("victim", 6, &root.join("victim"), &par, &|o| {
            with_faults(o, "stall:step@step=3");
        });
        let mut fo = FleetOptions::new(par.clone());
        fo.quantum = 2;
        fo.max_runs = 1;
        fo.supervisor = Some(SupervisorOptions {
            retries: 1,
            backoff: 1,
            stall_after: 2,
            ..SupervisorOptions::new()
        });
        let fleet = run_fleet(std::slice::from_ref(&victim), &fo).unwrap();

        let report = fleet.tenant("victim").unwrap();
        assert!(!report.completed(), "{label}: a wedge no rung fixes must die");
        assert_eq!(report.health, Health::Dead, "{label}: terminal health");
        assert_eq!(report.demotions, 2, "{label}: the ladder was walked first");
        let err = report.error.as_deref().unwrap();
        assert!(err.contains("stalled"), "{label}: verdict names the stall, got {err:?}");
        std::fs::remove_dir_all(root).ok();
    }
}

// ---------------------------------------------------------------------------
// The crash-safe fleet manifest
// ---------------------------------------------------------------------------

fn supervised_opts(manifest: &Path) -> SupervisorOptions {
    SupervisorOptions {
        manifest: Some(manifest.to_path_buf()),
        ..SupervisorOptions::new()
    }
}

fn manifest_fleet(
    root: &Path,
    sub: &str,
    par: &Parallelism,
    so: SupervisorOptions,
) -> FleetOutcome {
    let nop: &dyn Fn(&mut TrainerOptions) = &|_| {};
    let tenants = vec![
        mk_tenant("a", 6, &root.join(sub).join("a"), par, nop),
        mk_tenant("b", 2, &root.join(sub).join("b"), par, nop),
        mk_tenant("c", 5, &root.join(sub).join("c"), par, nop),
    ];
    let mut fo = FleetOptions::new(par.clone());
    fo.quantum = 2;
    fo.max_runs = 2;
    fo.supervisor = Some(so);
    run_fleet(&tenants, &fo).expect("fleet completes")
}

/// Kill the supervisor after two rounds (the `halt_after` hook — every
/// completed round's manifest is on disk), then `--auto-resume` the
/// whole fleet: the resumed fleet's schedule log continues the crashed
/// one's exactly, and every tenant — including the short one that
/// already *finished* before the crash, whose outcome is reconstructed
/// by the trainer's finished-replay path — ends bitwise identical to
/// the uninterrupted fleet, at 1, 2 and 13 threads.
#[test]
fn fleet_auto_resume_after_supervisor_crash_is_bitwise() {
    for (label, par) in thread_sweep() {
        let root = tmpdir(&format!("fleetresume_{label}"));
        let cont_manifest = root.join("cont").join("fleet.manifest");
        let crash_manifest = root.join("crash").join("fleet.manifest");
        let continuous =
            manifest_fleet(&root, "cont", &par, supervised_opts(&cont_manifest));

        let crashed = manifest_fleet(
            &root,
            "crash",
            &par,
            SupervisorOptions { halt_after: Some(2), ..supervised_opts(&crash_manifest) },
        );
        assert!(crashed.halted, "{label}: the simulated crash must trip");
        assert!(crash_manifest.exists(), "{label}: manifest persisted per round");

        let resumed = manifest_fleet(
            &root,
            "crash",
            &par,
            SupervisorOptions { auto_resume: true, ..supervised_opts(&crash_manifest) },
        );
        assert!(!resumed.halted, "{label}");
        assert_eq!(resumed.schedule, continuous.schedule, "{label}: schedule log");
        assert_eq!(resumed.rounds, continuous.rounds, "{label}: round count");
        for id in ["a", "b", "c"] {
            let r = resumed.tenant(id).unwrap();
            let c = continuous.tenant(id).unwrap();
            assert!(r.completed(), "{label}/{id}: {:?}", r.error);
            assert_eq!(r.health, Health::Healthy, "{label}/{id}");
            assert_outcomes_bitwise_eq(
                r.outcome.as_ref().unwrap(),
                c.outcome.as_ref().unwrap(),
                &format!("{label}/{id}"),
            );
            assert_eq!(
                final_fingerprint(&root.join("crash").join(id), ARTIFACT),
                final_fingerprint(&root.join("cont").join(id), ARTIFACT),
                "{label}/{id}: final checkpoint state"
            );
        }
        std::fs::remove_dir_all(root).ok();
    }
}

/// Manifest failure modes: a manifest for a *different* fleet (tenant
/// set or slicing) refuses to resume — caller error, not corruption —
/// while a corrupt manifest fails its CRC and degrades to a fresh
/// ledger: the fleet still completes, and because every tenant resumes
/// from its own intact checkpoint ring, the final per-tenant state is
/// bitwise identical to the uninterrupted fleet's.
#[test]
fn corrupt_manifest_degrades_to_a_fresh_ledger_not_a_dead_fleet() {
    let par = Parallelism::serial();
    let root = tmpdir("manifest_rec");
    let cont_manifest = root.join("cont").join("fleet.manifest");
    let crash_manifest = root.join("crash").join("fleet.manifest");
    let continuous = manifest_fleet(&root, "cont", &par, supervised_opts(&cont_manifest));

    let crashed = manifest_fleet(
        &root,
        "crash",
        &par,
        SupervisorOptions { halt_after: Some(2), ..supervised_opts(&crash_manifest) },
    );
    assert!(crashed.halted);

    // A different tenant set refuses to resume (same manifest path).
    {
        let nop: &dyn Fn(&mut TrainerOptions) = &|_| {};
        let strangers = vec![
            mk_tenant("x", 6, &root.join("crash").join("a"), &par, nop),
            mk_tenant("y", 2, &root.join("crash").join("b"), &par, nop),
            mk_tenant("z", 5, &root.join("crash").join("c"), &par, nop),
        ];
        let mut fo = FleetOptions::new(par.clone());
        fo.quantum = 2;
        fo.max_runs = 2;
        fo.supervisor = Some(SupervisorOptions {
            auto_resume: true,
            ..supervised_opts(&crash_manifest)
        });
        let err = run_fleet(&strangers, &fo).expect_err("stranger fleet must not resume");
        assert!(
            format!("{err:#}").contains("different tenant set"),
            "got {err:#}"
        );
    }

    // A different quantum refuses too (the bitwise contract needs the
    // original slicing).
    {
        let nop: &dyn Fn(&mut TrainerOptions) = &|_| {};
        let tenants = vec![
            mk_tenant("a", 6, &root.join("crash").join("a"), &par, nop),
            mk_tenant("b", 2, &root.join("crash").join("b"), &par, nop),
            mk_tenant("c", 5, &root.join("crash").join("c"), &par, nop),
        ];
        let mut fo = FleetOptions::new(par.clone());
        fo.quantum = 3;
        fo.max_runs = 2;
        fo.supervisor = Some(SupervisorOptions {
            auto_resume: true,
            ..supervised_opts(&crash_manifest)
        });
        let err = run_fleet(&tenants, &fo).expect_err("resliced fleet must not resume");
        assert!(format!("{err:#}").contains("quantum"), "got {err:#}");
    }

    // Tamper with the manifest: the CRC trailer rejects it at load and
    // the resume falls back to a fresh ledger instead of dying.
    let mut bytes = std::fs::read(&crash_manifest).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x20;
    std::fs::write(&crash_manifest, &bytes).unwrap();

    let resumed = manifest_fleet(
        &root,
        "crash",
        &par,
        SupervisorOptions { auto_resume: true, ..supervised_opts(&crash_manifest) },
    );
    for id in ["a", "b", "c"] {
        let r = resumed.tenant(id).unwrap();
        let c = continuous.tenant(id).unwrap();
        assert!(r.completed(), "{id}: {:?}", r.error);
        assert_outcomes_bitwise_eq(
            r.outcome.as_ref().unwrap(),
            c.outcome.as_ref().unwrap(),
            id,
        );
        assert_eq!(
            final_fingerprint(&root.join("crash").join(id), ARTIFACT),
            final_fingerprint(&root.join("cont").join(id), ARTIFACT),
            "{id}: final checkpoint state"
        );
    }
    std::fs::remove_dir_all(root).ok();
}

// ---------------------------------------------------------------------------
// The cooperative stop flag
// ---------------------------------------------------------------------------

/// The stop flag preempts mid-quantum at the next step boundary,
/// exactly like a `stop_after` the setter didn't pick in advance: the
/// run suspends after the in-flight step with a forced suspension
/// checkpoint, and a later auto-resume completes the trajectory
/// bitwise identical to an uninterrupted run.
#[test]
fn stop_flag_suspends_mid_quantum_like_stop_after() {
    let par = Parallelism::serial();
    let d_cont = tmpdir("flag_cont");
    let d_flag = tmpdir("flag_stop");
    let continuous = solo(&d_cont, 6, &par);

    let rt = Runtime::host(ModelConfig::TINY);
    let mut o = opts_in(&d_flag, 6, &par);
    o.stop_flag = Some(Arc::new(AtomicBool::new(true)));
    let stopped = Trainer::new(&rt, TrainConfig::config1(6)).run(&o).unwrap();
    assert_eq!(stopped.records.len(), 1, "suspends after the in-flight step");
    assert!(
        TrainCheckpoint::load(&d_flag.join(format!("{ARTIFACT}.step1.ckpt"))).is_ok(),
        "forced suspension checkpoint"
    );

    let mut o = opts_in(&d_flag, 6, &par);
    o.auto_resume = true;
    let resumed = Trainer::new(&rt, TrainConfig::config1(6)).run(&o).unwrap();
    assert_outcomes_bitwise_eq(&continuous, &resumed, "resume after stop flag");
    std::fs::remove_dir_all(d_cont).ok();
    std::fs::remove_dir_all(d_flag).ok();
}
