//! The parallel engine's correctness contract: for any thread count,
//! `fake_quantize`, `compute_scales`, all four GEMM paths, the
//! weighted recipe sweep and the full overlapped host train step
//! produce results **bit-identical** to the serial path — on the
//! deque/steal scheduler (default), the legacy shared-queue pool, the
//! spawn engine, and at whatever thread count `MOR_THREADS` selects
//! (the CI determinism matrix runs this suite at 1, 2, 4 and 13
//! threads; 2 is the minimal stealing case). The kernel layer extends
//! the same contract along a second axis: the packed/blocked GEMM
//! microkernels, the AVX2 SIMD twins, the LUT QDQ and the fused
//! quantize-on-pack path must all match the scalar reference loops
//! bitwise (`blocked_gemm_equals_naive_bitwise_adversarial`,
//! `simd_gemm_rounding_boundary_inputs_match_scalar_bitwise`,
//! `fused_pack_equals_quantize_then_matmul_bitwise`,
//! `host_train_step_kernel_engine_equals_scalar_oracle_bitwise`,
//! `host_train_step_simd_equals_scalar_oracle_bitwise`). The CI matrix
//! additionally re-runs the suite with `MOR_NO_SIMD=1`, pinning the
//! blocked-scalar oracle lane on hosts where AVX2 is present.
//! Also pins `Histogram::bin_of` to the paper's 0.5%-wide bin edges.

use mor::coordinator::checkpoint::Checkpoint;
use mor::coordinator::trainer::{TrainOutcome, Trainer, TrainerOptions};
use mor::formats::ReprType;
use mor::kernels::gemm::{nt_panel, pack_b, pack_bt, tn_panel, MR, NR};
use mor::model::config::{ModelConfig, TrainConfig};
use mor::mor::recipes::{Recipe, RecipeKind, SubTensorMode};
use mor::mor::stats::{Histogram, HIST_BINS};
use mor::quant::fake_quant::fake_quantize_with;
use mor::quant::partition::Partition;
use mor::runtime::host::{mor_quantize, mor_quantize_packed, HostQuant};
use mor::runtime::Runtime;
use mor::scaling::{compute_scales_with, ScalingAlgo};
use mor::tensor::ops::{
    matmul_naive_with, matmul_nt_naive_with, matmul_nt_with, matmul_packed_with,
    matmul_tn_naive_with, matmul_tn_with, matmul_with, mixed_gemm_with, BlockTypes,
};
use mor::tensor::Tensor;
use mor::util::par::{Engine, KernelMode, Parallelism};
use mor::util::proptest::{prop, Gen};

/// A worker pool with the serial cutoff disabled, so even tiny test
/// tensors exercise the parallel path.
fn pool(threads: usize) -> Parallelism {
    Parallelism::pooled(threads, 1)
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: element {i}: {x} vs {y}");
    }
}

fn random_tensor(g: &mut Gen, max_side: usize) -> Tensor {
    let rows = g.usize_in(1, max_side);
    let cols = g.usize_in(1, max_side);
    let data = (0..rows * cols)
        .map(|_| g.f32_in(-1.0, 1.0) * g.f32_log_uniform(1e-4, 1e3))
        .collect();
    Tensor::from_vec(&[rows, cols], data)
}

#[test]
fn prop_fake_quantize_parallel_equals_serial() {
    prop(120, |g: &mut Gen| {
        let x = random_tensor(g, 40);
        let t = *g.choose(&[ReprType::E4M3, ReprType::E5M2, ReprType::Bf16, ReprType::NvFp4]);
        let (br, bc, sl) = (g.usize_in(1, 9), g.usize_in(1, 9), g.usize_in(1, 8));
        let p = *g.choose(&[
            Partition::Tensor,
            Partition::Block { r: br, c: bc },
            Partition::ChannelRows,
            Partition::ChannelCols,
            Partition::SubChannelRows { len: sl },
        ]);
        let s = *g.choose(&[ScalingAlgo::Gam, ScalingAlgo::AmaxFp32, ScalingAlgo::E8M0]);
        let threads = g.usize_in(2, 8);

        let serial = fake_quantize_with(&x, t, p, s, &Parallelism::serial());
        let parallel = fake_quantize_with(&x, t, p, s, &pool(threads));

        assert_bits_eq(serial.out.data(), parallel.out.data(), "fake_quantize out");
        assert_eq!(serial.block_err, parallel.block_err, "block_err");
        assert_eq!(serial.global_err, parallel.global_err, "global_err");
        assert_eq!(serial.block_range, parallel.block_range, "block_range");
        assert_eq!(serial.scales.blocks, parallel.scales.blocks, "scales");
        assert_eq!(
            serial.scales.group_mantissa.to_bits(),
            parallel.scales.group_mantissa.to_bits(),
            "group mantissa"
        );
        true
    });
}

#[test]
fn prop_compute_scales_parallel_equals_serial() {
    prop(200, |g: &mut Gen| {
        let n = g.usize_in(1, 600);
        let group_amax = g.f32_log_uniform(1e-6, 1e6);
        let amaxes: Vec<f32> = (0..n)
            .map(|_| if g.f32() < 0.05 { 0.0 } else { group_amax * g.f32_in(1e-5, 1.0) })
            .collect();
        let algo = *g.choose(&[ScalingAlgo::Gam, ScalingAlgo::AmaxFp32, ScalingAlgo::E8M0]);
        let threads = g.usize_in(2, 8);
        let serial =
            compute_scales_with(algo, 448.0, group_amax, &amaxes, &Parallelism::serial());
        let parallel = compute_scales_with(algo, 448.0, group_amax, &amaxes, &pool(threads));
        assert_eq!(serial.blocks, parallel.blocks);
        assert_eq!(
            serial.group_mantissa.to_bits(),
            parallel.group_mantissa.to_bits()
        );
        assert_eq!(serial.metadata_bits(), parallel.metadata_bits());
        true
    });
}

#[test]
fn prop_gemms_parallel_equal_serial() {
    prop(80, |g: &mut Gen| {
        let m = g.usize_in(1, 33);
        let k = g.usize_in(1, 33);
        let n = g.usize_in(1, 33);
        let a = Tensor::from_vec(&[m, k], (0..m * k).map(|_| g.f32_in(-2.0, 2.0)).collect());
        let b = Tensor::from_vec(&[k, n], (0..k * n).map(|_| g.f32_in(-2.0, 2.0)).collect());
        let threads = g.usize_in(2, 8);
        let cfg = pool(threads);

        let c_s = matmul_with(&a, &b, &Parallelism::serial());
        let c_p = matmul_with(&a, &b, &cfg);
        assert_bits_eq(c_s.data(), c_p.data(), "matmul");

        let at = a.transpose();
        let tn_s = matmul_tn_with(&at, &b, &Parallelism::serial());
        let tn_p = matmul_tn_with(&at, &b, &cfg);
        assert_bits_eq(tn_s.data(), tn_p.data(), "matmul_tn");

        let bt = b.transpose();
        let nt_s = matmul_nt_with(&a, &bt, &Parallelism::serial());
        let nt_p = matmul_nt_with(&a, &bt, &cfg);
        assert_bits_eq(nt_s.data(), nt_p.data(), "matmul_nt");
        true
    });
}

#[test]
fn prop_mixed_gemm_parallel_equals_serial() {
    prop(60, |g: &mut Gen| {
        let m = g.usize_in(1, 40);
        let k = g.usize_in(1, 40);
        let n = g.usize_in(1, 40);
        let blk = g.usize_in(1, 12);
        let a = Tensor::from_vec(&[m, k], (0..m * k).map(|_| g.f32_in(-2.0, 2.0)).collect());
        let b = Tensor::from_vec(&[k, n], (0..k * n).map(|_| g.f32_in(-2.0, 2.0)).collect());
        let mut ta = BlockTypes::uniform(m, k, blk, ReprType::E4M3);
        let mut tb = BlockTypes::uniform(k, n, blk, ReprType::E4M3);
        for row in ta.grid.iter_mut() {
            for t in row.iter_mut() {
                *t = *g.choose(&[ReprType::E4M3, ReprType::E5M2, ReprType::Bf16, ReprType::NvFp4]);
            }
        }
        for row in tb.grid.iter_mut() {
            for t in row.iter_mut() {
                *t = *g.choose(&[ReprType::E4M3, ReprType::E5M2, ReprType::Bf16, ReprType::NvFp4]);
            }
        }
        let threads = g.usize_in(2, 8);
        let serial = mixed_gemm_with(&a, &ta, &b, &tb, &Parallelism::serial());
        let parallel = mixed_gemm_with(&a, &ta, &b, &tb, &pool(threads));
        assert_bits_eq(serial.out.data(), parallel.out.data(), "mixed_gemm out");
        assert_eq!(serial.macs, parallel.macs, "mixed_gemm macs");
        true
    });
}

#[test]
fn prop_recipe_sweep_parallel_equals_serial() {
    prop(30, |g: &mut Gen| {
        let tensors: Vec<Tensor> = (0..g.usize_in(2, 6)).map(|_| random_tensor(g, 24)).collect();
        let refs: Vec<&Tensor> = tensors.iter().collect();
        let recipe = Recipe {
            kind: *g.choose(&[
                RecipeKind::TensorLevel { threshold: 0.045 },
                RecipeKind::SubTensor { mode: SubTensorMode::TwoWay },
                RecipeKind::SubTensor { mode: SubTensorMode::ThreeWay },
            ]),
            partition: *g.choose(&[
                Partition::Tensor,
                Partition::Block { r: 5, c: 5 },
                Partition::ChannelRows,
            ]),
            scaling: *g.choose(&[ScalingAlgo::Gam, ScalingAlgo::AmaxFp32]),
        };
        let serial = recipe.apply_batch_with(&refs, &Parallelism::serial());
        let parallel = recipe.apply_batch_with(&refs, &pool(g.usize_in(2, 6)));
        assert_eq!(serial.len(), parallel.len());
        for (s, p) in serial.iter().zip(parallel.iter()) {
            assert_bits_eq(s.out.data(), p.out.data(), "sweep out");
            assert_eq!(s.block_types, p.block_types);
            assert_eq!(s.e4m3_relerr.to_bits(), p.e4m3_relerr.to_bits());
            assert_eq!(s.bf16_fraction.to_bits(), p.bf16_fraction.to_bits());
            assert_eq!(s.metadata_bits, p.metadata_bits);
        }
        true
    });
}

/// The spawn engine (scoped thread per chunk), the shared-queue pool
/// and the deque/steal scheduler must all agree bit-for-bit: same
/// chunking, different scheduling.
#[test]
fn prop_spawn_engine_equals_pool_engine() {
    prop(40, |g: &mut Gen| {
        let x = random_tensor(g, 32);
        let threads = g.usize_in(2, 8);
        let steal_cfg = pool(threads); // Engine::Steal is the default
        let shared_cfg = pool(threads).with_engine(Engine::Pool);
        let spawn_cfg = pool(threads).with_engine(Engine::Spawn);
        let (t, p, alg) = (ReprType::E4M3, Partition::BLOCK128, ScalingAlgo::Gam);
        let a = fake_quantize_with(&x, t, p, alg, &steal_cfg);
        let b = fake_quantize_with(&x, t, p, alg, &spawn_cfg);
        let c = fake_quantize_with(&x, t, p, alg, &shared_cfg);
        assert_bits_eq(a.out.data(), b.out.data(), "steal-vs-spawn parity");
        assert_bits_eq(a.out.data(), c.out.data(), "steal-vs-pool parity");
        assert_eq!(a.block_err, b.block_err);
        assert_eq!(a.block_err, c.block_err);
        true
    });
}

/// Adversarial chunk shapes for the stealing scheduler, at the exact
/// thread counts the CI determinism matrix pins (2 = minimal stealing
/// case, 3, 13): 1-element chunks, chunk counts of worker-count ± 1
/// (one deque empty / one chunk spilling past the round-robin), and
/// counts far past the deque bound. Every shape must match serial
/// bitwise on both pooled engines.
#[test]
fn adversarial_chunk_shapes_match_serial_bitwise() {
    let f = |i: usize| ((i as f32) * 0.7311).sin() * (1.0 + (i % 17) as f32);
    for threads in [2usize, 3, 13] {
        let steal = pool(threads);
        let shared = pool(threads).with_engine(Engine::Pool);
        for n in [1usize, threads - 1, threads, threads + 1, 4 * threads + 1, 97] {
            let serial: Vec<u32> = (0..n).map(|i| f(i).to_bits()).collect();
            let a: Vec<u32> =
                mor::util::par::par_map(&steal, n, f).iter().map(|v| v.to_bits()).collect();
            let b: Vec<u32> =
                mor::util::par::par_map(&shared, n, f).iter().map(|v| v.to_bits()).collect();
            assert_eq!(serial, a, "steal par_map diverged at {threads} threads, n={n}");
            assert_eq!(serial, b, "pool par_map diverged at {threads} threads, n={n}");
        }
        // 1-element-chunk quantizations: a 1xN tensor under a 1x1 block
        // partition makes every chunk a single element.
        let x = Tensor::from_vec(&[1, 29], (0..29).map(f).collect());
        let one = Partition::Block { r: 1, c: 1 };
        let ser = Parallelism::serial();
        let s = fake_quantize_with(&x, ReprType::E4M3, one, ScalingAlgo::Gam, &ser);
        let p = fake_quantize_with(&x, ReprType::E4M3, one, ScalingAlgo::Gam, &steal);
        assert_bits_eq(s.out.data(), p.out.data(), "1-element chunks");
        assert_eq!(s.block_err, p.block_err);
    }
}

/// The weighted sweep scheduler on its target workload — one giant
/// tensor plus many tiny items — must stay bitwise equal to the serial
/// sweep at the matrix thread counts, for both sub-tensor recipes.
#[test]
fn weighted_sweep_giant_plus_tiny_matches_serial_bitwise() {
    let giant = Tensor::normal(&[96, 96], 1.0, 41);
    let tinies: Vec<Tensor> = (0..11)
        .map(|i| {
            let side = 1 + (i % 4);
            Tensor::normal(&[side, side + 1], 1.0, 100 + i as u64)
        })
        .collect();
    // Giant deliberately NOT first in input order: weighted dispatch
    // must reorder scheduling without reordering results.
    let mut refs: Vec<&Tensor> = tinies.iter().take(5).collect();
    refs.push(&giant);
    refs.extend(tinies.iter().skip(5));
    for kind in [
        RecipeKind::TensorLevel { threshold: 0.045 },
        RecipeKind::SubTensor { mode: SubTensorMode::TwoWay },
        RecipeKind::SubTensor { mode: SubTensorMode::ThreeWay },
    ] {
        let recipe = Recipe {
            kind,
            partition: Partition::Block { r: 5, c: 5 },
            scaling: ScalingAlgo::Gam,
        };
        let serial = recipe.apply_batch_with(&refs, &Parallelism::serial());
        for threads in [2usize, 3, 13] {
            let parallel = recipe.apply_batch_with(&refs, &pool(threads));
            assert_eq!(serial.len(), parallel.len());
            for (i, (s, p)) in serial.iter().zip(parallel.iter()).enumerate() {
                assert_bits_eq(
                    s.out.data(),
                    p.out.data(),
                    &format!("weighted sweep item {i} at {threads} threads"),
                );
                assert_eq!(s.block_types, p.block_types);
                assert_eq!(s.e4m3_relerr.to_bits(), p.e4m3_relerr.to_bits());
                assert_eq!(s.bf16_fraction.to_bits(), p.bf16_fraction.to_bits());
                assert_eq!(s.metadata_bits, p.metadata_bits);
            }
        }
    }
}

/// `MOR_THREADS`-driven config (what the CI determinism matrix varies):
/// `Parallelism::auto()` with the cutoff disabled must match serial
/// bitwise at whatever thread count the environment selected.
#[test]
fn auto_env_config_matches_serial_bitwise() {
    let mut auto = Parallelism::auto();
    auto.min_items = 1;
    let x = Tensor::from_vec(
        &[37, 29],
        (0..37 * 29).map(|i| ((i as f32) * 0.7311).sin() * (1.0 + (i % 17) as f32)).collect(),
    );
    for t in [ReprType::E4M3, ReprType::E5M2, ReprType::Bf16] {
        let ser = Parallelism::serial();
        let serial = fake_quantize_with(&x, t, Partition::BLOCK128, ScalingAlgo::Gam, &ser);
        let parallel = fake_quantize_with(&x, t, Partition::BLOCK128, ScalingAlgo::Gam, &auto);
        assert_bits_eq(serial.out.data(), parallel.out.data(), "auto-config fake_quantize");
        assert_eq!(serial.block_err, parallel.block_err);
    }
    let a = matmul_with(&x, &x.transpose(), &Parallelism::serial());
    let b = matmul_with(&x, &x.transpose(), &auto);
    assert_bits_eq(a.data(), b.data(), "auto-config matmul");
}

/// The packed register-tiled GEMM kernels are bitwise equal to the
/// naive reference loops across adversarial shapes: 1×1, k=1, single
/// row/column, register-tile boundaries (MR/NR ± 1), worker-count ± 1
/// row counts, and ragged everything — forced through the blocked
/// kernels directly (below the dispatch cutoff) and through the
/// dispatching entry points at the CI matrix thread counts.
#[test]
fn blocked_gemm_equals_naive_bitwise_adversarial() {
    let mk = |rows: usize, cols: usize, seed: u64| {
        let mut t = Tensor::normal(&[rows, cols], 1.0, seed);
        for (i, v) in t.data_mut().iter_mut().enumerate() {
            if i % 4 == 0 {
                *v = 0.0; // exercise the zero-skip paths
            }
        }
        t
    };
    let ser = Parallelism::serial();
    let shapes: Vec<(usize, usize, usize)> = vec![
        (1, 1, 1),
        (1, 5, 1),
        (3, 1, NR + 1),
        (2, 7, NR - 1),
        (4, 16, NR),
        (5, 40, 2),
        (12, 3, 2 * NR + 3),
        (13, 17, 33), // above the dispatch cutoff
        (33, 29, 31),
    ];
    for &(m, k, n) in &shapes {
        let a = mk(m, k, (m * 7 + k) as u64 + 1);
        let b = mk(k, n, (k * 5 + n) as u64 + 2);
        let at = a.transpose();
        let bt = b.transpose();
        let nn_ref = matmul_naive_with(&a, &b, &ser);
        let tn_ref = matmul_tn_naive_with(&at, &b, &ser);
        let nt_ref = matmul_nt_naive_with(&a, &bt, &ser);

        // Forced blocked kernels (no size cutoff): packed nn entry,
        // tn/nt panel kernels over the full row range.
        let bp = pack_b(&b);
        assert_bits_eq(
            matmul_packed_with(&a, &bp, &ser).data(),
            nn_ref.data(),
            &format!("packed nn {m}x{k}x{n}"),
        );
        let mut c = Tensor::zeros(&[m, n]);
        tn_panel(at.data(), m, &bp, c.data_mut(), 0, m);
        assert_bits_eq(c.data(), tn_ref.data(), &format!("blocked tn {m}x{k}x{n}"));
        let btp = pack_bt(&bt);
        let mut c = Tensor::zeros(&[m, n]);
        nt_panel(a.data(), k, &btp, c.data_mut(), 0, m);
        assert_bits_eq(c.data(), nt_ref.data(), &format!("blocked nt {m}x{k}x{n}"));

        // Dispatching entry points at the CI matrix thread counts, in
        // both engine modes — Simd (the default) and Blocked (the
        // `MOR_NO_SIMD=1` oracle): parallel ≡ serial naive, bitwise,
        // for worker counts straddling the row count.
        for threads in [2usize, 3, 13] {
            assert_eq!(pool(threads).kernel(), KernelMode::Simd);
            for mode in [KernelMode::Simd, KernelMode::Blocked] {
                let cfg = pool(threads).with_kernel(mode);
                assert_bits_eq(
                    matmul_with(&a, &b, &cfg).data(),
                    nn_ref.data(),
                    &format!("nn dispatch {m}x{k}x{n} t{threads} {mode:?}"),
                );
                assert_bits_eq(
                    matmul_tn_with(&at, &b, &cfg).data(),
                    tn_ref.data(),
                    &format!("tn dispatch {m}x{k}x{n} t{threads} {mode:?}"),
                );
                assert_bits_eq(
                    matmul_nt_with(&a, &bt, &cfg).data(),
                    nt_ref.data(),
                    &format!("nt dispatch {m}x{k}x{n} t{threads} {mode:?}"),
                );
            }
        }
    }
}

/// SIMD GEMM ≡ scalar on rounding-boundary inputs: operand values are
/// chosen so products carry sub-ulp tails that a fused multiply-add
/// would round differently from the reference's separate mul-then-add
/// (two roundings). Any FMA contraction hiding in the vector kernels
/// fails this bitwise, as would any re-association of the k loop.
/// Shapes cover 1×1, k=1, register-tile boundaries (MR/NR ± 1) and row
/// counts straddling the 2/3/13-thread worker counts of the CI matrix.
#[test]
fn simd_gemm_rounding_boundary_inputs_match_scalar_bitwise() {
    // Values with long mantissa tails and mixed magnitudes: EPSILON
    // neighbours of 1.0, non-terminating binary fractions, subnormal
    // boundaries and a magnitude large enough that mul-then-add loses
    // bits the FMA would keep. Zeros exercise the skip paths.
    let vals = [
        1.0f32 + f32::EPSILON,
        1.0 - f32::EPSILON / 2.0,
        1.0 / 3.0,
        -7.0 / 11.0,
        16_777_216.0, // 2^24: addend ulp boundary
        f32::MIN_POSITIVE,
        -f32::MIN_POSITIVE * 1.5, // subnormal products
        0.0,
        1e30,
        -3.0e-5,
    ];
    let mk = |rows: usize, cols: usize, salt: usize| {
        let data =
            (0..rows * cols).map(|i| vals[(i * 7 + salt) % vals.len()]).collect::<Vec<f32>>();
        Tensor::from_vec(&[rows, cols], data)
    };
    let shapes = [
        (1usize, 1usize, 1usize),
        (1, 1, NR),
        (MR - 1, 3, NR - 1),
        (MR + 1, 2, NR + 1),
        (2, 1, 2 * NR),
        (3, 24, NR),
        (12, 9, 5), // 13 workers, 12 rows
        (14, 5, 2 * NR + 3),
    ];
    let scalar_ser = Parallelism::serial().with_kernel(KernelMode::Scalar);
    for &(m, k, n) in &shapes {
        let a = mk(m, k, 1);
        let b = mk(k, n, 4);
        let at = a.transpose();
        let bt = b.transpose();
        let nn_ref = matmul_with(&a, &b, &scalar_ser);
        let tn_ref = matmul_tn_with(&at, &b, &scalar_ser);
        let nt_ref = matmul_nt_with(&a, &bt, &scalar_ser);
        for threads in [1usize, 2, 3, 13] {
            let base = if threads == 1 { Parallelism::serial() } else { pool(threads) };
            let cfg = base.with_kernel(KernelMode::Simd);
            assert_bits_eq(
                matmul_with(&a, &b, &cfg).data(),
                nn_ref.data(),
                &format!("simd nn boundary {m}x{k}x{n} t{threads}"),
            );
            assert_bits_eq(
                matmul_tn_with(&at, &b, &cfg).data(),
                tn_ref.data(),
                &format!("simd tn boundary {m}x{k}x{n} t{threads}"),
            );
            assert_bits_eq(
                matmul_nt_with(&a, &bt, &cfg).data(),
                nt_ref.data(),
                &format!("simd nt boundary {m}x{k}x{n} t{threads}"),
            );
        }
    }
}

/// Fused quantize-on-pack ≡ quantize-then-matmul, bitwise: for every
/// recipe class (incl. per-channel partitions, where the backward dy
/// requantizes per direction), `mor_quantize_packed` + the packed GEMM
/// must reproduce `mor_quantize` + the dispatching GEMM exactly, and
/// the scalar oracle must agree with both.
#[test]
fn fused_pack_equals_quantize_then_matmul_bitwise() {
    let mut w = Tensor::normal(&[20, 24], 1.0, 31);
    for (i, v) in w.data_mut().iter_mut().enumerate() {
        *v *= (10.0f32).powi((i % 9) as i32 - 4); // wide range → mixed decisions
    }
    let x = Tensor::normal(&[17, 20], 0.7, 32);
    for (recipe, partition, scaling) in [
        ("baseline", "tensor", "gam"),
        ("tensor_level", "block128x128", "gam"),
        ("subtensor2", "block4x4", "gam"),
        ("subtensor3", "block4x4", "gam"),
        ("subtensor3", "channel", "amax"),
    ] {
        let q = HostQuant::from_fields(recipe, partition, scaling).unwrap();
        for threads in [1usize, 2, 13] {
            let cfg = if threads == 1 { Parallelism::serial() } else { pool(threads) };
            let scalar_cfg = cfg.clone().with_kernel(KernelMode::Scalar);
            let (qw, re_m, fb_m) = mor_quantize(&q, &w, 0.045, 1, &cfg);
            let (pw, re_p, fb_p) = mor_quantize_packed(&q, &w, 0.045, 1, &cfg);
            assert_eq!(re_m.to_bits(), re_p.to_bits(), "{recipe} relerr t{threads}");
            assert_eq!(fb_m.to_bits(), fb_p.to_bits(), "{recipe} fallback t{threads}");
            assert_bits_eq(
                pack_b(&qw).data(),
                pw.data(),
                &format!("{recipe}/{partition} fused pack t{threads}"),
            );
            // quantize → matmul along three routes: fused-packed,
            // materialized blocked, materialized scalar oracle.
            let fused = matmul_packed_with(&x, &pw, &cfg);
            let unfused = matmul_with(&x, &qw, &cfg);
            let (qw_s, _, _) = mor_quantize(&q, &w, 0.045, 1, &scalar_cfg);
            let scalar = matmul_with(&x, &qw_s, &scalar_cfg);
            assert_bits_eq(fused.data(), unfused.data(), &format!("{recipe} fused GEMM"));
            assert_bits_eq(fused.data(), scalar.data(), &format!("{recipe} scalar GEMM"));
        }
    }
}

/// The kernel engine (LUT QDQ + packed GEMM + fused pack) and the
/// scalar oracle produce bit-identical full host train steps at the CI
/// matrix thread counts — the end-to-end statement of the kernel
/// layer's bit-exactness contract.
#[test]
fn host_train_step_kernel_engine_equals_scalar_oracle_bitwise() {
    let run = |par: Parallelism| -> (Vec<u32>, Vec<f32>, Vec<f32>) {
        let rt = Runtime::host(ModelConfig::TINY).with_parallelism(par);
        let mut s = rt.train_session("train_mor_subtensor_three_way", 23).unwrap();
        let tokens: Vec<i32> = (0..s.batch * s.seq).map(|i| (i % 239) as i32).collect();
        let mut losses = Vec::new();
        let mut out = None;
        for _ in 0..2 {
            let o = s.step(&tokens, 1e-3, 0.045).unwrap();
            losses.push(o.loss.to_bits());
            out = Some(o);
        }
        let o = out.unwrap();
        (losses, o.relerr, o.fallback)
    };
    let oracle = run(Parallelism::serial().with_kernel(KernelMode::Scalar));
    let kernel_serial = run(Parallelism::serial());
    assert_eq!(oracle.0, kernel_serial.0, "serial kernel engine diverged from oracle");
    assert_bits_eq(&oracle.1, &kernel_serial.1, "relerr slots (serial)");
    assert_bits_eq(&oracle.2, &kernel_serial.2, "fallback slots (serial)");
    for threads in [2, 13] {
        let kernel = run(pool(threads));
        assert_eq!(oracle.0, kernel.0, "kernel engine diverged at {threads} threads");
        assert_bits_eq(&oracle.1, &kernel.1, "relerr slots");
        assert_bits_eq(&oracle.2, &kernel.2, "fallback slots");
    }
}

/// Step-level SIMD statement of the contract: the explicit `Simd`
/// engine and the `Blocked` (`MOR_NO_SIMD=1`) oracle mode both
/// reproduce the scalar-oracle host train step bitwise — losses,
/// per-slot relative errors and fallback fractions — serially and at
/// the CI matrix thread counts. On hosts without AVX2 the `Simd` leg
/// degenerates to `Blocked` and the assertion still holds.
#[test]
fn host_train_step_simd_equals_scalar_oracle_bitwise() {
    let run = |par: Parallelism| -> (Vec<u32>, Vec<f32>, Vec<f32>) {
        let rt = Runtime::host(ModelConfig::TINY).with_parallelism(par);
        let mut s = rt.train_session("train_mor_subtensor_three_way", 37).unwrap();
        let tokens: Vec<i32> = (0..s.batch * s.seq).map(|i| (i % 229) as i32).collect();
        let mut losses = Vec::new();
        let mut out = None;
        for _ in 0..2 {
            let o = s.step(&tokens, 1e-3, 0.045).unwrap();
            losses.push(o.loss.to_bits());
            out = Some(o);
        }
        let o = out.unwrap();
        (losses, o.relerr, o.fallback)
    };
    let oracle = run(Parallelism::serial().with_kernel(KernelMode::Scalar));
    for mode in [KernelMode::Simd, KernelMode::Blocked] {
        let serial = run(Parallelism::serial().with_kernel(mode));
        assert_eq!(oracle.0, serial.0, "{mode:?} serial losses diverged from scalar oracle");
        assert_bits_eq(&oracle.1, &serial.1, &format!("{mode:?} relerr slots (serial)"));
        assert_bits_eq(&oracle.2, &serial.2, &format!("{mode:?} fallback slots (serial)"));
        for threads in [2usize, 13] {
            let kernel = run(pool(threads).with_kernel(mode));
            assert_eq!(oracle.0, kernel.0, "{mode:?} diverged at {threads} threads");
            assert_bits_eq(&oracle.1, &kernel.1, &format!("{mode:?} relerr slots t{threads}"));
            assert_bits_eq(&oracle.2, &kernel.2, &format!("{mode:?} fallback t{threads}"));
        }
    }
}

/// The full overlapped host train step — pipeline-parallel operand
/// quantizations inside `linear_bwd`, GEMM overlap, pool engine — is
/// bit-identical to the strictly serial step, including at the awkward
/// 13-thread count the CI matrix pins.
#[test]
fn host_train_step_parallel_equals_serial_bitwise() {
    let run = |par: Parallelism| -> (Vec<u32>, Vec<f32>, Vec<f32>) {
        let rt = Runtime::host(ModelConfig::TINY).with_parallelism(par);
        let mut s = rt.train_session("train_mor_subtensor_three_way", 11).unwrap();
        let tokens: Vec<i32> = (0..s.batch * s.seq).map(|i| (i % 251) as i32).collect();
        let mut losses = Vec::new();
        let mut out = None;
        for _ in 0..2 {
            let o = s.step(&tokens, 1e-3, 0.045).unwrap();
            losses.push(o.loss.to_bits());
            out = Some(o);
        }
        let o = out.unwrap();
        (losses, o.relerr, o.fallback)
    };
    let serial = run(Parallelism::serial());
    for threads in [2, 3, 13] {
        let parallel = run(Parallelism::pooled(threads, 1));
        assert_eq!(serial.0, parallel.0, "losses diverged at {threads} threads");
        assert_bits_eq(&serial.1, &parallel.1, "relerr slots");
        assert_bits_eq(&serial.2, &parallel.2, "fallback slots");
    }
}

/// The resume ≡ continuous contract: training N steps, checkpointing,
/// restarting the whole process path (fresh runtime, trainer, session,
/// loaders) and training M more steps is **bitwise identical** to one
/// uninterrupted N+M-step run — params, metrics rows (minus the
/// wall-clock step_ms column), MoR decision fractions and heatmaps,
/// eval-suite trajectory, data cursors, RNG streams and amax
/// histories. Verified at the in-test thread counts 2/3/13 plus
/// whatever `MOR_THREADS` the CI determinism matrix selects (1/2/4/13
/// via `Parallelism::auto`).
#[test]
fn resume_equals_continuous_bitwise() {
    const SPLIT: u64 = 3;
    const TOTAL: u64 = 6;
    const ARTIFACT: &str = "train_mor_tensor_block";

    let base = std::env::temp_dir().join(format!("mor_resume_{}", std::process::id()));
    let mk_opts = |steps: u64, out: std::path::PathBuf, par: Parallelism| {
        let mut o = TrainerOptions::new(ARTIFACT, steps, out);
        o.val_every = 2;
        o.suite_every = 3;
        o.ckpt_every = SPLIT;
        o.stats_window = 2;
        o.quiet = true;
        o.parallelism = Some(par);
        o
    };
    // Each leg builds its own runtime + trainer + session from scratch:
    // the only shared state is what the checkpoint file carries.
    let run = |steps: u64,
               out: std::path::PathBuf,
               par: Parallelism,
               resume: Option<std::path::PathBuf>|
     -> TrainOutcome {
        let rt = Runtime::host(ModelConfig::TINY);
        let trainer = Trainer::new(&rt, TrainConfig::config1(TOTAL));
        let mut opts = mk_opts(steps, out, par);
        opts.resume = resume;
        trainer.run(&opts).unwrap()
    };

    let mut cases: Vec<(String, Parallelism)> =
        [2usize, 3, 13].iter().map(|t| (format!("t{t}"), pool(*t))).collect();
    // Honor the CI matrix: MOR_THREADS drives auto() in every cell.
    cases.push(("auto".into(), Parallelism::auto()));

    for (tag, par) in cases {
        let cont_dir = base.join(format!("{tag}_cont"));
        let split_dir = base.join(format!("{tag}_split"));

        // The continuous run checkpoints mid-run at step 3 — exactly
        // what a kill-and-restart would resume from.
        let cont = run(TOTAL, cont_dir.clone(), par.clone(), None);
        let ckpt = cont_dir.join(format!("{ARTIFACT}.step{SPLIT}.ckpt"));
        assert!(ckpt.exists(), "[{tag}] mid-run checkpoint missing");
        // Restart the whole process path from it, into a fresh out dir,
        // with the same total step count.
        let res = run(TOTAL, split_dir.clone(), par.clone(), Some(ckpt));

        // Outcome parity: every record field except wall-clock step_ms.
        assert_eq!(res.records.len(), cont.records.len(), "[{tag}] record count");
        for (a, b) in cont.records.iter().zip(res.records.iter()) {
            assert_eq!(a.step, b.step, "[{tag}] step");
            assert_eq!(a.lr.to_bits(), b.lr.to_bits(), "[{tag}] lr @{}", a.step);
            assert_eq!(
                a.train_loss.to_bits(),
                b.train_loss.to_bits(),
                "[{tag}] train_loss @{}",
                a.step
            );
            assert_eq!(
                a.val_loss.to_bits(),
                b.val_loss.to_bits(),
                "[{tag}] val_loss @{}",
                a.step
            );
            assert_eq!(
                a.param_norm.to_bits(),
                b.param_norm.to_bits(),
                "[{tag}] param_norm @{}",
                a.step
            );
            assert_eq!(
                a.bf16_fallback_rate.to_bits(),
                b.bf16_fallback_rate.to_bits(),
                "[{tag}] fallback @{}",
                a.step
            );
            assert_eq!(
                a.mean_relerr.to_bits(),
                b.mean_relerr.to_bits(),
                "[{tag}] relerr @{}",
                a.step
            );
        }
        assert_eq!(
            cont.final_train_loss.to_bits(),
            res.final_train_loss.to_bits(),
            "[{tag}] final train loss"
        );
        assert_eq!(
            cont.final_val_loss.to_bits(),
            res.final_val_loss.to_bits(),
            "[{tag}] final val loss"
        );

        // metrics.csv parity: byte-identical rows minus the trailing
        // step_ms column (wall-clock time is timing, not state).
        let strip = |path: &std::path::Path| -> Vec<String> {
            std::fs::read_to_string(path)
                .unwrap()
                .lines()
                .map(|l| l.rsplit_once(',').unwrap().0.to_string())
                .collect()
        };
        let csv = format!("{ARTIFACT}.config1.csv");
        assert_eq!(
            strip(&cont_dir.join(&csv)),
            strip(&split_dir.join(&csv)),
            "[{tag}] metrics.csv rows diverged"
        );

        // MoR decision fractions + full heatmaps.
        assert_eq!(
            cont.stats.overall_fallback_pct().to_bits(),
            res.stats.overall_fallback_pct().to_bits(),
            "[{tag}] fallback pct"
        );
        assert_eq!(
            cont.stats.heatmap_csv(),
            res.stats.heatmap_csv(),
            "[{tag}] stats heatmap"
        );

        // Eval-suite trajectory.
        assert_eq!(cont.suite_history.len(), res.suite_history.len(), "[{tag}] suite len");
        for ((sa, a), (sb, b)) in cont.suite_history.iter().zip(res.suite_history.iter()) {
            assert_eq!(sa, sb, "[{tag}] suite step");
            assert_eq!(a.per_task.len(), b.per_task.len());
            for ((na, la, aa), (nb, lb, ab)) in a.per_task.iter().zip(b.per_task.iter()) {
                assert_eq!(na, nb);
                assert_eq!(la.to_bits(), lb.to_bits(), "[{tag}] suite loss {na}");
                assert_eq!(aa.to_bits(), ab.to_bits(), "[{tag}] suite acc {na}");
            }
        }

        // Strongest check: the final step-6 checkpoints agree section
        // by section — params bitwise, and every state section
        // (optimizer moments, data cursors, RNG streams, amax
        // histories, stats, suite, meta, telemetry) byte-identical.
        // Only metrics/records may differ, in its step_ms bits.
        let ca = Checkpoint::load(&cont_dir.join(format!("{ARTIFACT}.step{TOTAL}.ckpt")))
            .unwrap();
        let cb = Checkpoint::load(&split_dir.join(format!("{ARTIFACT}.step{TOTAL}.ckpt")))
            .unwrap();
        assert_eq!(ca.step, cb.step, "[{tag}] final ckpt step");
        assert_eq!(ca.tensors.len(), cb.tensors.len());
        for ((na, ta), (nb, tb)) in ca.tensors.iter().zip(cb.tensors.iter()) {
            assert_eq!(na, nb);
            assert_bits_eq(ta.data(), tb.data(), &format!("[{tag}] param {na}"));
        }
        assert_eq!(ca.sections.len(), cb.sections.len());
        for ((na, pa), (nb, pb)) in ca.sections.iter().zip(cb.sections.iter()) {
            assert_eq!(na, nb, "[{tag}] section order");
            if na == "metrics/records" {
                continue; // carries wall-clock step_ms bits
            }
            assert_eq!(pa, pb, "[{tag}] section {na} diverged");
        }
    }

    // A resume with mismatched pinned numerics options must be
    // rejected loudly, not silently diverge: wrong total steps (the
    // classic remaining-count mistake changes the LR schedule) and a
    // wrong threshold both error.
    let ckpt = base.join("auto_cont").join(format!("{ARTIFACT}.step{SPLIT}.ckpt"));
    let rt = Runtime::host(ModelConfig::TINY);
    let trainer = Trainer::new(&rt, TrainConfig::config1(TOTAL));
    let mut bad = mk_opts(TOTAL + 2, base.join("bad"), Parallelism::auto());
    bad.resume = Some(ckpt.clone());
    assert!(trainer.run(&bad).is_err(), "steps mismatch must be rejected");
    let mut bad = mk_opts(TOTAL, base.join("bad"), Parallelism::auto());
    bad.threshold = 0.05;
    bad.resume = Some(ckpt.clone());
    assert!(trainer.run(&bad).is_err(), "threshold mismatch must be rejected");

    // Digest guard: checkpoints store a metrics row-count + content
    // hash and replay the prefix from the on-disk metrics.csv — a
    // tampered file must be rejected loudly, never silently resumed.
    let csv_path = base.join("auto_cont").join(format!("{ARTIFACT}.config1.csv"));
    let original = std::fs::read_to_string(&csv_path).unwrap();
    let mut lines: Vec<String> = original.lines().map(str::to_string).collect();
    lines[1].push('1'); // corrupt the first data row
    std::fs::write(&csv_path, lines.join("\n") + "\n").unwrap();
    let mut bad = mk_opts(TOTAL, base.join("bad"), Parallelism::auto());
    bad.resume = Some(ckpt);
    assert!(trainer.run(&bad).is_err(), "metrics digest mismatch must be rejected");
    std::fs::remove_dir_all(base).ok();
}

/// The paper's histogram: 0.5%-wide bins, first bin `< 0.5%`, last bin
/// `>= 5.5%`, threshold values land in the bin to their right.
#[test]
fn histogram_bin_edges_are_exact() {
    assert_eq!(HIST_BINS, 12);
    // Exact paper edges.
    assert_eq!(Histogram::bin_of(0.0), 0);
    assert_eq!(Histogram::bin_of(0.005), 1);
    assert_eq!(Histogram::bin_of(0.045), 9); // the 4.5% threshold bin
    assert_eq!(Histogram::bin_of(0.050), 10);
    assert_eq!(Histogram::bin_of(0.055), 11);
    assert_eq!(Histogram::bin_of(123.0), 11); // overflow bin
    assert_eq!(Histogram::bin_of(-1e-9), 0); // negatives clamp to bin 0
    // Just-below / just-above every edge k*0.5%.
    for k in 1..=11usize {
        let edge = k as f64 * 0.005;
        assert_eq!(Histogram::bin_of(edge - 1e-7), k - 1, "below edge {k}");
        assert_eq!(Histogram::bin_of(edge + 1e-7), k.min(HIST_BINS - 1), "above edge {k}");
    }
    // Mid-bin values.
    for k in 0..HIST_BINS {
        let mid = (k as f64 + 0.5) * 0.005;
        assert_eq!(Histogram::bin_of(mid), k.min(HIST_BINS - 1), "mid of bin {k}");
    }
}
