//! Paper-shape micro-reproduction: fast, deterministic checks of each
//! table/figure's directional claims on host-mirror numerics (the full
//! regeneration with training runs is `repro report <exp>`; this bench
//! verifies the *shape* cheaply on every `cargo bench`).

use mor::formats::ReprType;
use mor::mor::recipes::{Recipe, RecipeKind, SubTensorMode};
use mor::quant::fake_quant::fake_quantize;
use mor::quant::partition::Partition;
use mor::scaling::ScalingAlgo;
use mor::tensor::Tensor;

/// Synthetic stand-ins for the three tensor populations the paper's
/// heatmaps identify: well-behaved (most tensors), wide-range
/// (FC2-activation-like), and extreme (first-layer-FC1-grad-like).
fn populations() -> Vec<(&'static str, Tensor)> {
    let smooth = Tensor::normal(&[256, 256], 2.0, 1);
    let mut wide = Tensor::normal(&[256, 256], 1.0, 2);
    for (i, v) in wide.data_mut().iter_mut().enumerate() {
        *v *= (10.0f32).powi((i % 7) as i32 - 3);
    }
    let mut extreme = Tensor::normal(&[256, 256], 1.0, 3);
    for (i, v) in extreme.data_mut().iter_mut().enumerate() {
        *v *= (10.0f32).powi((i % 13) as i32 - 6);
    }
    vec![("smooth", smooth), ("wide", wide), ("extreme", extreme)]
}

fn main() {
    println!("== paper-shape checks (host mirror) ==\n");
    let pops = populations();

    // Fig. 10 shape: fallback ordering channel <= block <= tensor.
    println!("Fig.10 shape — BF16 fallback by partition strategy (th 4.5%):");
    let mut rates = Vec::new();
    for (label, partition) in [
        ("channel", Partition::ChannelRows),
        ("block", Partition::BLOCK128),
        ("tensor", Partition::Tensor),
    ] {
        let recipe = Recipe {
            kind: RecipeKind::TensorLevel { threshold: 0.045 },
            partition,
            scaling: ScalingAlgo::Gam,
        };
        let fb = pops.iter().map(|(_, t)| recipe.apply(t).bf16_fraction).sum::<f64>()
            / pops.len() as f64;
        println!("  {label:<8} fallback {:.1}%", fb * 100.0);
        rates.push(fb);
    }
    assert!(rates[0] <= rates[1] && rates[1] <= rates[2], "Fig.10 ordering violated");
    println!("  ordering channel <= block <= tensor HOLDS\n");

    // Table 3 shape: GAM/E8M0 relerr <= 2x amax relerr; finer blocks help.
    println!("Table 3 shape — scaling algos & block size (relerr on wide tensor):");
    let wide = &pops[1].1;
    let mut es = Vec::new();
    for algo in [ScalingAlgo::Gam, ScalingAlgo::AmaxFp32, ScalingAlgo::E8M0] {
        let e = fake_quantize(wide, ReprType::E4M3, Partition::BLOCK128, algo).global_err.mean();
        println!("  {:<5} {:.3}%", algo.name(), e * 100.0);
        es.push(e);
    }
    let e64 =
        fake_quantize(wide, ReprType::E4M3, Partition::BLOCK64, ScalingAlgo::Gam).global_err.mean();
    println!("  block64 (gam) {:.3}%  (128: {:.3}%)", e64 * 100.0, es[0] * 100.0);
    assert!(e64 <= es[0] * 1.05, "finer blocks should not hurt");

    // Table 4 / Fig. 20 shape: three-way quantizes at least as many
    // blocks as two-way (E5M2 absorbs some BF16 fallbacks).
    println!("\nTable 4 shape — sub-tensor recipes on wide tensor:");
    let two = Recipe {
        kind: RecipeKind::SubTensor { mode: SubTensorMode::TwoWay },
        partition: Partition::Block { r: 64, c: 64 },
        scaling: ScalingAlgo::Gam,
    }
    .apply(wide);
    let three = Recipe {
        kind: RecipeKind::SubTensor { mode: SubTensorMode::ThreeWay },
        partition: Partition::Block { r: 64, c: 64 },
        scaling: ScalingAlgo::Gam,
    }
    .apply(wide);
    println!(
        "  two-way:   {:.0}% blocks BF16",
        two.type_fractions()[2] * 100.0
    );
    println!(
        "  three-way: {:.0}% blocks BF16, {:.0}% E5M2",
        three.type_fractions()[2] * 100.0,
        three.type_fractions()[1] * 100.0
    );
    assert!(three.type_fractions()[2] <= two.type_fractions()[2] + 1e-9);

    // Fig. 14 shape: growing dynamic range pushes relerr over threshold.
    println!("\nFig.14 shape — relerr grows with dynamic range (per-tensor scale):");
    for d in [0i32, 2, 4, 6] {
        let mut t = Tensor::normal(&[128, 128], 1.0, 40 + d as u64);
        for (i, v) in t.data_mut().iter_mut().enumerate() {
            *v *= (10.0f32).powi((i % (2 * d + 1) as usize) as i32 - d);
        }
        let e = fake_quantize(&t, ReprType::E4M3, Partition::Tensor, ScalingAlgo::Gam)
            .global_err
            .mean();
        println!(
            "  spread 10^±{d}: relerr {:.2}% {}",
            e * 100.0,
            if e > 0.045 { "→ BF16 fallback" } else { "→ E4M3" }
        );
    }
    println!("\nall paper-shape checks passed");
}
