//! Host GEMM benches: the plain f32 GEMM vs the Fig. 3 mixed-type
//! blocked GEMM (which also models the fp8-vs-upcast MAC accounting),
//! serial vs spawn vs the shared-queue pool vs the deque/steal
//! scheduler over output-row panels, plus the kernel-layer rows —
//! **naive triple loop vs packed register-tiled microkernel vs the
//! AVX2 SIMD twin** for every variant, and **fused quantize-on-pack vs
//! quantize-then-pack** for the MoR linear-operand path.
//!
//! `--json <path>` merges the rows into the machine-readable perf
//! snapshot (`BENCH_9.json`); `--warmup-ms/--measure-ms/--min-batches`
//! shrink the budgets for CI.

use mor::formats::ReprType;
use mor::kernels::gemm::pack_b;
use mor::runtime::host::{mor_quantize, mor_quantize_packed, HostQuant};
use mor::tensor::ops::{
    matmul_nt_with, matmul_tn_with, matmul_with, mixed_gemm_with, BlockTypes,
};
use mor::tensor::Tensor;
use mor::util::bench::{bench, report_throughput, BenchOptions, JsonSnapshot};
use mor::util::cli::Args;
use mor::util::par::{engine_comparison_rows, kernel_comparison_rows, Parallelism};
use std::hint::black_box;

fn main() {
    let args = Args::from_env();
    let opts = BenchOptions::default().with_args(&args);
    let mut snap = JsonSnapshot::from_args("host_gemm", &args);
    const N: usize = 256;
    let a = Tensor::normal(&[N, N], 1.0, 1);
    let b = Tensor::normal(&[N, N], 1.0, 2);
    let flops = (2 * N * N * N) as f64;
    let at = a.transpose();
    let bt = b.transpose();
    let ta = BlockTypes::uniform(N, N, 32, ReprType::E4M3);
    let mut tb = BlockTypes::uniform(N, N, 32, ReprType::E4M3);
    tb.grid[0][0] = ReprType::Bf16;
    tb.grid[1][1] = ReprType::E5M2;

    // Kernel-layer rows at the default engine/thread configuration:
    // the scalar oracle (naive loops) vs the packed blocked kernels vs
    // the AVX2 SIMD microkernels, per GEMM variant — the headline
    // scalar/blocked/simd comparison (the simd row falls back to
    // blocked on hosts without AVX2).
    for (label, cfg) in kernel_comparison_rows() {
        let mut rows: Vec<(String, mor::util::bench::BenchResult)> = Vec::new();
        let r = bench(&format!("matmul_{N}_kernel_{label}"), &opts, || {
            black_box(matmul_with(black_box(&a), black_box(&b), &cfg));
        });
        rows.push((format!("matmul_kernel_{label}"), r));
        let r = bench(&format!("matmul_tn_{N}_kernel_{label}"), &opts, || {
            black_box(matmul_tn_with(black_box(&at), black_box(&b), &cfg));
        });
        rows.push((format!("matmul_tn_kernel_{label}"), r));
        let r = bench(&format!("matmul_nt_{N}_kernel_{label}"), &opts, || {
            black_box(matmul_nt_with(black_box(&a), black_box(&bt), &cfg));
        });
        rows.push((format!("matmul_nt_kernel_{label}"), r));
        let r = bench(&format!("mixed_gemm_{N}_blk32_kernel_{label}"), &opts, || {
            black_box(mixed_gemm_with(black_box(&a), &ta, black_box(&b), &tb, &cfg));
        });
        rows.push((format!("mixed_gemm_kernel_{label}"), r));
        for (name, r) in &rows {
            report_throughput(name, r, flops, "flop");
            if let Some(s) = &mut snap {
                s.record(r);
                s.record_throughput(name, r, flops, "flop");
            }
        }
    }

    // Fused quantize-on-pack vs the unfused materialize-then-pack
    // sequence for one MoR weight operand (identical pack bits; the
    // fused row skips the full materialize+re-read pass).
    {
        let q = HostQuant::from_fields("subtensor3", "block32x32", "gam").unwrap();
        let cfg = Parallelism::auto();
        let r = bench(&format!("quantize_pack_unfused_{N}"), &opts, || {
            let (qw, re, _) = mor_quantize(&q, black_box(&b), 0.045, 1, &cfg);
            black_box((pack_b(&qw), re));
        });
        report_throughput("quantize_pack_unfused", &r, (N * N) as f64, "elem");
        if let Some(s) = &mut snap {
            s.record(&r);
            s.record_throughput("quantize_pack_unfused", &r, (N * N) as f64, "elem");
        }
        let r = bench(&format!("quantize_pack_fused_{N}"), &opts, || {
            let (pw, re, _) = mor_quantize_packed(&q, black_box(&b), 0.045, 1, &cfg);
            black_box((pw, re));
        });
        report_throughput("quantize_pack_fused", &r, (N * N) as f64, "elem");
        if let Some(s) = &mut snap {
            s.record(&r);
            s.record_throughput("quantize_pack_fused", &r, (N * N) as f64, "elem");
        }
    }

    for (label, cfg) in engine_comparison_rows() {
        let mut rows: Vec<(String, mor::util::bench::BenchResult)> = Vec::new();

        let r = bench(&format!("matmul_f32_{N}_{label}"), &opts, || {
            black_box(matmul_with(black_box(&a), black_box(&b), &cfg));
        });
        rows.push((format!("matmul_f32_{label}"), r));

        let r = bench(&format!("matmul_tn_{N}_{label}"), &opts, || {
            black_box(matmul_tn_with(black_box(&at), black_box(&b), &cfg));
        });
        rows.push((format!("matmul_tn_{label}"), r));

        let r = bench(&format!("matmul_nt_{N}_{label}"), &opts, || {
            black_box(matmul_nt_with(black_box(&a), black_box(&bt), &cfg));
        });
        rows.push((format!("matmul_nt_{label}"), r));

        let r = bench(&format!("mixed_gemm_{N}_blk32_{label}"), &opts, || {
            black_box(mixed_gemm_with(black_box(&a), &ta, black_box(&b), &tb, &cfg));
        });
        rows.push((format!("mixed_gemm_{label}"), r));

        for (name, r) in &rows {
            report_throughput(name, r, flops, "flop");
            if let Some(s) = &mut snap {
                s.record(r);
                s.record_throughput(name, r, flops, "flop");
            }
        }
    }
    println!(
        "(parallel rows = {} threads, row-panel chunking)",
        Parallelism::auto().threads
    );
    if let Some(s) = &snap {
        s.write(Parallelism::auto().threads).expect("writing bench snapshot");
    }
}
