//! Host GEMM benches: the plain f32 GEMM vs the Fig. 3 mixed-type
//! blocked GEMM (which also models the fp8-vs-upcast MAC accounting).

use mor::formats::ReprType;
use mor::tensor::ops::{matmul, matmul_nt, matmul_tn, mixed_gemm, BlockTypes};
use mor::tensor::Tensor;
use mor::util::bench::{bench, report_throughput, BenchOptions};
use std::hint::black_box;

fn main() {
    let opts = BenchOptions::default();
    const N: usize = 128;
    let a = Tensor::normal(&[N, N], 1.0, 1);
    let b = Tensor::normal(&[N, N], 1.0, 2);
    let flops = (2 * N * N * N) as f64;

    let r = bench("matmul_f32_128", &opts, || {
        black_box(matmul(black_box(&a), black_box(&b)));
    });
    report_throughput("matmul_f32", &r, flops, "flop");

    let at = a.transpose();
    let r = bench("matmul_tn_128", &opts, || {
        black_box(matmul_tn(black_box(&at), black_box(&b)));
    });
    report_throughput("matmul_tn", &r, flops, "flop");

    let bt = b.transpose();
    let r = bench("matmul_nt_128", &opts, || {
        black_box(matmul_nt(black_box(&a), black_box(&bt)));
    });
    report_throughput("matmul_nt", &r, flops, "flop");

    let ta = BlockTypes::uniform(N, N, 32, ReprType::E4M3);
    let mut tb = BlockTypes::uniform(N, N, 32, ReprType::E4M3);
    tb.grid[0][0] = ReprType::Bf16;
    tb.grid[1][1] = ReprType::E5M2;
    let r = bench("mixed_gemm_128_blk32", &opts, || {
        black_box(mixed_gemm(black_box(&a), &ta, black_box(&b), &tb));
    });
    report_throughput("mixed_gemm", &r, flops, "flop");
}
