//! Host GEMM benches: the plain f32 GEMM vs the Fig. 3 mixed-type
//! blocked GEMM (which also models the fp8-vs-upcast MAC accounting),
//! each serial vs parallel over output-row panels.

use mor::formats::ReprType;
use mor::tensor::ops::{
    matmul_nt_with, matmul_tn_with, matmul_with, mixed_gemm_with, BlockTypes,
};
use mor::tensor::Tensor;
use mor::util::bench::{bench, report_throughput, BenchOptions};
use mor::util::par::Parallelism;
use std::hint::black_box;

fn main() {
    let opts = BenchOptions::default();
    const N: usize = 256;
    let a = Tensor::normal(&[N, N], 1.0, 1);
    let b = Tensor::normal(&[N, N], 1.0, 2);
    let flops = (2 * N * N * N) as f64;
    let at = a.transpose();
    let bt = b.transpose();
    let ta = BlockTypes::uniform(N, N, 32, ReprType::E4M3);
    let mut tb = BlockTypes::uniform(N, N, 32, ReprType::E4M3);
    tb.grid[0][0] = ReprType::Bf16;
    tb.grid[1][1] = ReprType::E5M2;

    let auto = Parallelism::auto();
    for (label, cfg) in [("serial", Parallelism::serial()), ("parallel", auto.clone())] {
        let r = bench(&format!("matmul_f32_{N}_{label}"), &opts, || {
            black_box(matmul_with(black_box(&a), black_box(&b), &cfg));
        });
        report_throughput(&format!("matmul_f32_{label}"), &r, flops, "flop");

        let r = bench(&format!("matmul_tn_{N}_{label}"), &opts, || {
            black_box(matmul_tn_with(black_box(&at), black_box(&b), &cfg));
        });
        report_throughput(&format!("matmul_tn_{label}"), &r, flops, "flop");

        let r = bench(&format!("matmul_nt_{N}_{label}"), &opts, || {
            black_box(matmul_nt_with(black_box(&a), black_box(&bt), &cfg));
        });
        report_throughput(&format!("matmul_nt_{label}"), &r, flops, "flop");

        let r = bench(&format!("mixed_gemm_{N}_blk32_{label}"), &opts, || {
            black_box(mixed_gemm_with(black_box(&a), &ta, black_box(&b), &tb, &cfg));
        });
        report_throughput(&format!("mixed_gemm_{label}"), &r, flops, "flop");
    }
    println!("(parallel = {} threads, row-panel chunking)", auto.threads);
}
