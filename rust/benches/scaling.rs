//! Scaling-algorithm cost: GAM (Alg. 1) vs FP32-amax vs E8M0, per block
//! — the §4.1.2 ablation's compute side. GAM's extra frexp/round-down
//! work should be noise against the amax reduction it shares with the
//! baselines.

use mor::scaling::{compute_scales, ScalingAlgo};
use mor::util::bench::{bench, report_throughput, BenchOptions};
use std::hint::black_box;

fn main() {
    let opts = BenchOptions::default();
    // 1024 block amaxes (a 4096x4096 tensor under 128x128 blocks).
    let amaxes: Vec<f32> = (0..1024).map(|i| 0.01 + ((i * 37) % 997) as f32).collect();
    let group_amax = amaxes.iter().cloned().fold(0.0f32, f32::max);

    for algo in [ScalingAlgo::Gam, ScalingAlgo::AmaxFp32, ScalingAlgo::E8M0] {
        let r = bench(&format!("compute_scales_{}_1024blocks", algo.name()), &opts, || {
            let s = compute_scales(algo, 448.0, black_box(group_amax), black_box(&amaxes));
            black_box(s);
        });
        report_throughput(&format!("scales_{}", algo.name()), &r, 1024.0, "block");
    }

    // Amax reduction itself (the shared, dominating cost): 128x128 block.
    let block: Vec<f32> = (0..128 * 128).map(|i| (i as f32).cos()).collect();
    let r = bench("block_amax_reduction_128x128", &opts, || {
        let m = block.iter().fold(0.0f32, |a, v| a.max(v.abs()));
        black_box(m);
    });
    report_throughput("block_amax_reduction", &r, (128 * 128) as f64, "elem");
}
