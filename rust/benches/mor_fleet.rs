//! Fleet-scheduler overhead: wall-clock for N tenants' worth of
//! training steps submitted through `coordinator::scheduler::run_fleet`
//! versus the same steps run back-to-back, at N = 1 / 2 / 4 tenants on
//! the shared pool. The `n1` row is the scheduler's fixed cost over a
//! bare `Trainer::run` (one tenant, one slice, no preemption); the
//! `n2`/`n4` rows show how run-granularity slices fill the pool.
//!
//! All runs are host-backend on the tiny preset with checkpointing and
//! validation off (quantum 0, `ckpt_every` 0), so the rows measure
//! scheduling + training compute, not ring I/O. `--json <path>` merges
//! the rows into the shared perf snapshot (`BENCH_9.json`).

use mor::coordinator::scheduler::{run_fleet, FleetOptions, Tenant};
use mor::coordinator::trainer::TrainerOptions;
use mor::model::config::{ModelConfig, TrainConfig};
use mor::util::bench::{bench, report_throughput, BenchOptions, JsonSnapshot};
use mor::util::cli::Args;
use mor::util::par::Parallelism;
use std::hint::black_box;
use std::time::Duration;

const STEPS: u64 = 3;

fn fleet_of(n: usize, root: &std::path::Path, par: &Parallelism) -> Vec<Tenant> {
    (0..n)
        .map(|i| {
            let id = format!("bench{i}");
            let mut opts = TrainerOptions::new(
                "train_mor_tensor_block",
                STEPS,
                root.join(&id),
            );
            opts.quiet = true;
            opts.val_every = 0;
            opts.parallelism = Some(par.clone());
            Tenant::new(&id, ModelConfig::TINY, TrainConfig::config1(STEPS), opts)
        })
        .collect()
}

fn main() {
    let args = Args::from_env();
    let opts = BenchOptions {
        warmup: Duration::from_millis(300),
        measure: Duration::from_millis(1500),
        min_batches: 2,
    }
    .with_args(&args);
    let mut snap = JsonSnapshot::from_args("mor_fleet", &args);

    let par = Parallelism::auto();
    let root = std::env::temp_dir().join(format!("mor_fleet_bench_{}", std::process::id()));
    println!("== fleet scheduler (tiny preset, {} steps/tenant, {} threads) ==", STEPS, par.threads);
    for n in [1usize, 2, 4] {
        let tenants = fleet_of(n, &root.join(format!("n{n}")), &par);
        let mut fo = FleetOptions::new(par.clone());
        fo.max_runs = n.max(1);
        let steps_per_iter = (n as u64 * STEPS) as f64;
        let r = bench(&format!("mor_fleet_n{n}"), &opts, || {
            let out = run_fleet(black_box(&tenants), &fo).expect("bench fleet");
            assert!(out.tenants.iter().all(|t| t.completed()));
            black_box(out.rounds);
        });
        report_throughput(&format!("mor_fleet_n{n}"), &r, steps_per_iter, "step");
        if let Some(s) = &mut snap {
            s.record(&r);
            s.record_throughput(&format!("mor_fleet_n{n}"), &r, steps_per_iter, "step");
        }
    }
    std::fs::remove_dir_all(&root).ok();

    if let Some(s) = &snap {
        s.write(par.threads).expect("writing bench snapshot");
    }
}
