//! End-to-end step latency (the L3 hot path), on both backends:
//!
//! * **Host backend** (always runs, no artifacts): one full train step
//!   per recipe variant on the tiny preset, serial vs the scoped-thread
//!   **spawn** engine vs the shared-queue **pool** vs the deque/**steal**
//!   scheduler — the headline comparison for the whole pipeline. All
//!   three pooled rows run the same chunking with the same thread
//!   count: the spawn→pool gap is the per-call spawn/join fixed
//!   overhead, and the pool→steal gap is the shared-queue contention
//!   the per-worker deques remove, so steal should sit at-or-below
//!   pool — especially on the mixed-size sweep workload below.
//! * **PJRT** (skips gracefully when artifacts are missing): the
//!   compiled-step latency per recipe variant, the standalone quant
//!   kernel, and the eval step.
//!
//! The `host_*_step_kernels_{scalar,kernel,simd}` row triple is the
//! kernel layer's headline comparison: the same full train step under
//! the scalar oracle (per-element QDQ + naive GEMM loops), the
//! table-driven LUT QDQ + packed blocked GEMM + fused quantize-on-pack
//! engine, and the AVX2 SIMD twins of both — bit-identical outputs,
//! only wall clock differs. On hosts without AVX2 the `simd` row
//! degenerates to the blocked row (same code path).
//!
//! `--json <path>` merges the rows into the machine-readable perf
//! snapshot (`BENCH_9.json`); `--warmup-ms/--measure-ms/--min-batches`
//! shrink the budgets for CI.

use mor::data::loader::BatchLoader;
use mor::data::synthetic::CorpusProfile;
use mor::model::config::ModelConfig;
use mor::mor::recipes::{Recipe, RecipeKind, SubTensorMode};
use mor::quant::partition::Partition;
use mor::runtime::Runtime;
use mor::scaling::ScalingAlgo;
use mor::tensor::Tensor;
use mor::util::bench::{bench, report_throughput, BenchOptions, JsonSnapshot};
use mor::util::cli::Args;
use mor::util::par::{engine_comparison_rows, kernel_comparison_rows, Parallelism};
use std::hint::black_box;
use std::path::Path;
use std::time::Duration;

fn host_backend_section(opts: &BenchOptions, snap: &mut Option<JsonSnapshot>) {
    let rt = Runtime::host(ModelConfig::TINY);
    let threads = Parallelism::auto().threads;
    println!(
        "== host backend (tiny preset; serial vs spawn vs pool vs steal at {threads} threads) =="
    );
    for artifact in ["train_baseline", "train_mor_tensor_block", "train_mor_subtensor_two_way"] {
        for (label, cfg) in engine_comparison_rows() {
            let mut session =
                rt.train_session_with(artifact, 1, cfg.clone()).expect("host session");
            let loader = BatchLoader::new(
                CorpusProfile::Nemotron4Like,
                256,
                session.batch,
                session.seq,
                1,
                0,
            );
            let batch = loader.next_batch();
            let tokens_per_step = (session.batch * session.seq) as f64;
            let r = bench(&format!("host_{artifact}_step_{label}"), opts, || {
                let out = session.step(black_box(&batch.tokens), 1e-3, 0.045).unwrap();
                black_box(out.loss);
            });
            report_throughput(&format!("host_{artifact}_{label}"), &r, tokens_per_step, "tok");
            if let Some(s) = snap {
                s.record(&r);
                s.record_throughput(
                    &format!("host_{artifact}_{label}"),
                    &r,
                    tokens_per_step,
                    "tok",
                );
            }
        }
    }
    // Kernel-engine rows on the default (steal) scheduler: the scalar
    // oracle vs the LUT QDQ + packed-GEMM + fused-pack layer vs the
    // AVX2 SIMD kernels, per artifact — the `step_latency` acceptance
    // rows for the kernel engine (same step, same bits, different
    // kernels).
    println!("== host backend kernel rows (scalar oracle vs blocked vs simd) ==");
    for artifact in ["train_baseline", "train_mor_tensor_block", "train_mor_subtensor_two_way"] {
        for (label, cfg) in kernel_comparison_rows() {
            let mut session =
                rt.train_session_with(artifact, 1, cfg.clone()).expect("host session");
            let loader = BatchLoader::new(
                CorpusProfile::Nemotron4Like,
                256,
                session.batch,
                session.seq,
                1,
                0,
            );
            let batch = loader.next_batch();
            let tokens_per_step = (session.batch * session.seq) as f64;
            let r = bench(&format!("host_{artifact}_step_kernels_{label}"), opts, || {
                let out = session.step(black_box(&batch.tokens), 1e-3, 0.045).unwrap();
                black_box(out.loss);
            });
            report_throughput(
                &format!("host_{artifact}_kernels_{label}"),
                &r,
                tokens_per_step,
                "tok",
            );
            if let Some(s) = snap {
                s.record(&r);
                s.record_throughput(
                    &format!("host_{artifact}_kernels_{label}"),
                    &r,
                    tokens_per_step,
                    "tok",
                );
            }
        }
    }

    // Standalone host quant kernel across the same engine rows. The
    // 256x256 input sits near the --par-min-block cutoff, which is
    // where the pooled engines' saved fixed overhead is most visible.
    for (label, cfg) in engine_comparison_rows() {
        let qs = rt.quant_session_with("quant_e4m3_gam_block128", cfg.clone()).unwrap();
        let x = Tensor::normal(&[qs.rows, qs.cols], 2.0, 3);
        let r = bench(&format!("host_quant_e4m3_gam_block128_{label}"), opts, || {
            let out = qs.run(black_box(&x)).unwrap();
            black_box(out.1);
        });
        report_throughput(
            &format!("host_quant_kernel_{label}"),
            &r,
            (qs.rows * qs.cols) as f64,
            "elem",
        );
        if let Some(s) = snap {
            s.record(&r);
            s.record_throughput(
                &format!("host_quant_kernel_{label}"),
                &r,
                (qs.rows * qs.cols) as f64,
                "elem",
            );
        }
    }
}

/// The weighted-sweep workload the steal scheduler targets: one giant
/// tensor plus many tiny ones through `Recipe::apply_batch_with`.
/// Under the old serial-inside-one-worker sweep the giant tensor set
/// the tail; with largest-first weighted dispatch it starts first and
/// stays chunk-parallel, so steal should beat pool here.
fn mixed_sweep_section(opts: &BenchOptions, snap: &mut Option<JsonSnapshot>) {
    println!("== mixed-size recipe sweep (1 giant + 12 tiny tensors) ==");
    let recipe = Recipe {
        kind: RecipeKind::SubTensor { mode: SubTensorMode::TwoWay },
        partition: Partition::Block { r: 32, c: 32 },
        scaling: ScalingAlgo::Gam,
    };
    let giant = Tensor::normal(&[256, 256], 1.0, 11);
    let tinies: Vec<Tensor> =
        (0..12).map(|i| Tensor::normal(&[16, 16], 1.0, 20 + i as u64)).collect();
    let mut tensors: Vec<&Tensor> = vec![&giant];
    tensors.extend(tinies.iter());
    let total_elems: f64 = tensors.iter().map(|t| t.len() as f64).sum();
    for (label, cfg) in engine_comparison_rows() {
        // Force the sweep onto the engine even for the tiny items.
        let mut cfg = cfg;
        cfg.min_items = 1;
        let r = bench(&format!("mixed_sweep_1giant_12tiny_{label}"), opts, || {
            let out = recipe.apply_batch_with(black_box(&tensors), &cfg);
            black_box(out.len());
        });
        report_throughput(&format!("mixed_sweep_{label}"), &r, total_elems, "elem");
        if let Some(s) = snap {
            s.record(&r);
            s.record_throughput(&format!("mixed_sweep_{label}"), &r, total_elems, "elem");
        }
    }
}

fn main() {
    let args = Args::from_env();
    let opts = BenchOptions {
        warmup: Duration::from_millis(500),
        measure: Duration::from_secs(3),
        min_batches: 5,
    }
    .with_args(&args);
    let mut snap = JsonSnapshot::from_args("step_latency", &args);

    host_backend_section(&opts, &mut snap);
    mixed_sweep_section(&opts, &mut snap);

    let dir = Path::new("artifacts/tiny");
    if !dir.join("manifest.txt").exists() {
        eprintln!("step_latency: artifacts/tiny missing — skipping the PJRT section");
        if let Some(s) = &snap {
            s.write(Parallelism::auto().threads).expect("writing bench snapshot");
        }
        return;
    }
    let rt = Runtime::load(dir, ModelConfig::TINY).expect("loading artifacts");

    for artifact in [
        "train_baseline",
        "train_mor_tensor_block",
        "train_mor_tensor_block_jnp", // same recipe, fused-jnp lowering
        "train_mor_tensor_tensor",
        "train_mor_tensor_channel",
        "train_mor_subtensor_two_way",
        "train_mor_subtensor_three_way",
    ] {
        let Ok(mut session) = rt.train_session(artifact, 1) else {
            eprintln!("skipping {artifact}: not in manifest (rebuild artifacts)");
            continue;
        };
        let loader =
            BatchLoader::new(CorpusProfile::Nemotron4Like, 256, session.batch, session.seq, 1, 0);
        let batch = loader.next_batch();
        let tokens_per_step = (session.batch * session.seq) as f64;
        let r = bench(&format!("{artifact}_step"), &opts, || {
            let out = session.step(black_box(&batch.tokens), 1e-3, 0.045).unwrap();
            black_box(out.loss);
        });
        report_throughput(artifact, &r, tokens_per_step, "tok");
        if let Some(s) = &mut snap {
            s.record(&r);
            s.record_throughput(artifact, &r, tokens_per_step, "tok");
        }
    }

    // Standalone Pallas quant kernel through PJRT.
    let qs = rt.quant_session("quant_e4m3_gam_block128").unwrap();
    let x = Tensor::normal(&[256, 256], 2.0, 3);
    let r = bench("quant_e4m3_gam_block128_pjrt", &opts, || {
        let out = qs.run(black_box(&x)).unwrap();
        black_box(out.1);
    });
    report_throughput("quant_kernel_pjrt", &r, (256 * 256) as f64, "elem");
    if let Some(s) = &mut snap {
        s.record(&r);
        s.record_throughput("quant_kernel_pjrt", &r, (256 * 256) as f64, "elem");
    }

    // Eval step (tensor-native interchange on the session params).
    let s = rt.train_session("train_baseline", 1).unwrap();
    let ev = rt.eval_session("eval").unwrap();
    let loader = BatchLoader::new(CorpusProfile::Nemotron4Like, 256, ev.batch, ev.seq, 2, 1);
    let batch = loader.next_batch();
    let mask = mor::coordinator::trainer::full_mask(ev.batch, ev.seq);
    let r = bench("eval_step", &opts, || {
        let out = ev.eval_params(s.params_ref(), black_box(&batch.tokens), &mask).unwrap();
        black_box(out);
    });
    report_throughput("eval_step", &r, (ev.batch * ev.seq) as f64, "tok");
    if let Some(s) = &mut snap {
        s.record(&r);
        s.record_throughput("eval_step", &r, (ev.batch * ev.seq) as f64, "tok");
    }

    if let Some(s) = &snap {
        s.write(Parallelism::auto().threads).expect("writing bench snapshot");
    }
}
