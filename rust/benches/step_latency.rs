//! End-to-end step latency (the L3 hot path), on both backends:
//!
//! * **Host backend** (always runs, no artifacts): one full train step
//!   per recipe variant on the tiny preset, serial vs the scoped-thread
//!   **spawn** engine vs the persistent worker **pool** — the headline
//!   comparison for the whole pipeline. The pool and spawn rows run the
//!   same chunking with the same thread count; the gap between them is
//!   exactly the per-call spawn/join fixed overhead the pool removes
//!   (hundreds of waves per host train step), so the pool row should
//!   sit at-or-below the spawn row.
//! * **PJRT** (skips gracefully when artifacts are missing): the
//!   compiled-step latency per recipe variant, the standalone quant
//!   kernel, and the eval step.

use mor::data::loader::BatchLoader;
use mor::data::synthetic::CorpusProfile;
use mor::model::config::ModelConfig;
use mor::runtime::Runtime;
use mor::tensor::Tensor;
use mor::util::bench::{bench, report_throughput, BenchOptions};
use mor::util::par::{Engine, Parallelism};
use std::hint::black_box;
use std::path::Path;
use std::time::Duration;

/// The three engine configurations under comparison. Fresh handles per
/// call so each bench row owns (and drops) its own pool.
fn engine_rows() -> [(&'static str, Parallelism); 3] {
    [
        ("serial", Parallelism::serial()),
        ("spawn", Parallelism::auto().with_engine(Engine::Spawn)),
        ("pool", Parallelism::auto()),
    ]
}

fn host_backend_section(opts: &BenchOptions) {
    let rt = Runtime::host(ModelConfig::TINY);
    let threads = Parallelism::auto().threads;
    println!("== host backend (tiny preset; serial vs spawn vs pool at {threads} threads) ==");
    for artifact in ["train_baseline", "train_mor_tensor_block", "train_mor_subtensor_two_way"] {
        for (label, cfg) in engine_rows() {
            let mut session =
                rt.train_session_with(artifact, 1, cfg.clone()).expect("host session");
            let loader = BatchLoader::new(
                CorpusProfile::Nemotron4Like,
                256,
                session.batch,
                session.seq,
                1,
                0,
            );
            let batch = loader.next_batch();
            let tokens_per_step = (session.batch * session.seq) as f64;
            let r = bench(&format!("host_{artifact}_step_{label}"), opts, || {
                let out = session.step(black_box(&batch.tokens), 1e-3, 0.045).unwrap();
                black_box(out.loss);
            });
            report_throughput(&format!("host_{artifact}_{label}"), &r, tokens_per_step, "tok");
        }
    }
    // Standalone host quant kernel across the same engine rows. The
    // 256x256 input sits near the --par-min-block cutoff, which is
    // where the pool's saved fixed overhead is most visible.
    for (label, cfg) in engine_rows() {
        let qs = rt.quant_session_with("quant_e4m3_gam_block128", cfg.clone()).unwrap();
        let x = Tensor::normal(&[qs.rows, qs.cols], 2.0, 3);
        let r = bench(&format!("host_quant_e4m3_gam_block128_{label}"), opts, || {
            let out = qs.run(black_box(&x)).unwrap();
            black_box(out.1);
        });
        report_throughput(
            &format!("host_quant_kernel_{label}"),
            &r,
            (qs.rows * qs.cols) as f64,
            "elem",
        );
    }
}

fn main() {
    let opts = BenchOptions {
        warmup: Duration::from_millis(500),
        measure: Duration::from_secs(3),
        min_batches: 5,
    };

    host_backend_section(&opts);

    let dir = Path::new("artifacts/tiny");
    if !dir.join("manifest.txt").exists() {
        eprintln!("step_latency: artifacts/tiny missing — skipping the PJRT section");
        return;
    }
    let rt = Runtime::load(dir, ModelConfig::TINY).expect("loading artifacts");

    for artifact in [
        "train_baseline",
        "train_mor_tensor_block",
        "train_mor_tensor_block_jnp", // same recipe, fused-jnp lowering
        "train_mor_tensor_tensor",
        "train_mor_tensor_channel",
        "train_mor_subtensor_two_way",
        "train_mor_subtensor_three_way",
    ] {
        let Ok(mut session) = rt.train_session(artifact, 1) else {
            eprintln!("skipping {artifact}: not in manifest (rebuild artifacts)");
            continue;
        };
        let loader =
            BatchLoader::new(CorpusProfile::Nemotron4Like, 256, session.batch, session.seq, 1, 0);
        let batch = loader.next_batch();
        let tokens_per_step = (session.batch * session.seq) as f64;
        let r = bench(&format!("{artifact}_step"), &opts, || {
            let out = session.step(black_box(&batch.tokens), 1e-3, 0.045).unwrap();
            black_box(out.loss);
        });
        report_throughput(artifact, &r, tokens_per_step, "tok");
    }

    // Standalone Pallas quant kernel through PJRT.
    let qs = rt.quant_session("quant_e4m3_gam_block128").unwrap();
    let x = Tensor::normal(&[256, 256], 2.0, 3);
    let r = bench("quant_e4m3_gam_block128_pjrt", &opts, || {
        let out = qs.run(black_box(&x)).unwrap();
        black_box(out.1);
    });
    report_throughput("quant_kernel_pjrt", &r, (256 * 256) as f64, "elem");

    // Eval step.
    let mut s = rt.train_session("train_baseline", 1).unwrap();
    let ev = rt.eval_session("eval").unwrap();
    let loader = BatchLoader::new(CorpusProfile::Nemotron4Like, 256, ev.batch, ev.seq, 2, 1);
    let batch = loader.next_batch();
    let mask = mor::coordinator::trainer::full_mask(ev.batch, ev.seq);
    let r = bench("eval_step", &opts, || {
        let out = ev.eval(s.param_literals(), black_box(&batch.tokens), &mask).unwrap();
        black_box(out);
    });
    report_throughput("eval_step", &r, (ev.batch * ev.seq) as f64, "tok");
}
