//! Codec throughput: fp8/bf16/fp4 encode-decode and the fake-quant
//! pipeline per element, the **scalar codec vs table-driven LUT QDQ vs
//! AVX2 SIMD QDQ** kernel comparison, plus the serial vs spawn vs pool
//! vs steal comparison of the full fake-quant pipeline on the chunked
//! engine. The L3-side perf floor for any host-side quantization work
//! (paper Section 2 claims "negligible overhead" for GAM metadata;
//! this bench quantifies the compute side).
//!
//! `--json <path>` merges the rows into the machine-readable perf
//! snapshot (`BENCH_9.json`); `--warmup-ms/--measure-ms/--min-batches`
//! shrink the budgets for CI.

use mor::formats::bf16;
use mor::formats::fp4;
use mor::formats::fp8::{Fp8Format, Rounding, E4M3, E5M2};
use mor::formats::ReprType;
use mor::kernels::qdq::QdqTables;
use mor::quant::fake_quant::fake_quantize_with;
use mor::quant::partition::Partition;
use mor::scaling::ScalingAlgo;
use mor::tensor::Tensor;
use mor::util::bench::{bench, report_throughput, BenchOptions, JsonSnapshot};
use mor::util::cli::Args;
use mor::util::par::{engine_comparison_rows, kernel_comparison_rows, Parallelism};
use std::hint::black_box;

fn main() {
    let args = Args::from_env();
    let opts = BenchOptions::default().with_args(&args);
    let mut snap = JsonSnapshot::from_args("quant_formats", &args);
    let xs: Vec<f32> =
        (0..4096).map(|i| ((i * 2654435761u64 as usize) as f32).sin() * 100.0).collect();

    let r = bench("e4m3_encode_decode_4k", &opts, || {
        let mut acc = 0f32;
        for x in &xs {
            acc += E4M3::quantize_dequantize(*x, Rounding::Saturate);
        }
        black_box(acc);
    });
    report_throughput("e4m3_encode_decode", &r, 4096.0, "elem");
    if let Some(s) = &mut snap {
        s.record(&r);
        s.record_throughput("e4m3_encode_decode", &r, 4096.0, "elem");
    }

    let r = bench("e5m2_encode_decode_4k", &opts, || {
        let mut acc = 0f32;
        for x in &xs {
            acc += E5M2::quantize_dequantize(*x, Rounding::Saturate);
        }
        black_box(acc);
    });
    report_throughput("e5m2_encode_decode", &r, 4096.0, "elem");
    if let Some(s) = &mut snap {
        s.record(&r);
        s.record_throughput("e5m2_encode_decode", &r, 4096.0, "elem");
    }

    // Table-driven LUT QDQ vs the scalar codec rows above — the
    // kernel-layer speedup at the single-element level (bit-identical
    // values by the parity tests; only the wall clock differs).
    let e4 = QdqTables::e4m3();
    let r = bench("e4m3_qdq_lut_4k", &opts, || {
        let mut acc = 0f32;
        for x in &xs {
            acc += e4.qdq_sat(*x);
        }
        black_box(acc);
    });
    report_throughput("e4m3_qdq_lut", &r, 4096.0, "elem");
    if let Some(s) = &mut snap {
        s.record(&r);
        s.record_throughput("e4m3_qdq_lut", &r, 4096.0, "elem");
    }

    let e5 = QdqTables::e5m2();
    let r = bench("e5m2_qdq_lut_4k", &opts, || {
        let mut acc = 0f32;
        for x in &xs {
            acc += e5.qdq_sat(*x);
        }
        black_box(acc);
    });
    report_throughput("e5m2_qdq_lut", &r, 4096.0, "elem");
    if let Some(s) = &mut snap {
        s.record(&r);
        s.record_throughput("e5m2_qdq_lut", &r, 4096.0, "elem");
    }

    let r = bench("bf16_roundtrip_4k", &opts, || {
        let mut acc = 0f32;
        for x in &xs {
            acc += bf16::quantize_dequantize(*x);
        }
        black_box(acc);
    });
    report_throughput("bf16_roundtrip", &r, 4096.0, "elem");
    if let Some(s) = &mut snap {
        s.record(&r);
        s.record_throughput("bf16_roundtrip", &r, 4096.0, "elem");
    }

    let mut out = vec![0f32; 4096];
    let r = bench("nvfp4_block_pipeline_4k", &opts, || {
        fp4::nvfp4_quantize_dequantize(black_box(&xs), &mut out);
        black_box(&out);
    });
    report_throughput("nvfp4_block_pipeline", &r, 4096.0, "elem");
    if let Some(s) = &mut snap {
        s.record(&r);
        s.record_throughput("nvfp4_block_pipeline", &r, 4096.0, "elem");
    }

    // Full fake-quant pipeline (Fig. 4), serial vs spawn vs pool vs
    // steal at the default thread count. This is the bench behind the
    // sweep-throughput claim: per-tensor metric collection must be
    // cheap enough to run every step.
    let x = Tensor::normal(&[512, 512], 2.0, 7);
    let elems = (512 * 512) as f64;
    for (label, cfg) in engine_comparison_rows() {
        for (pname, partition) in [
            ("block128", Partition::BLOCK128),
            ("channel", Partition::ChannelRows),
            ("subchannel32", Partition::SubChannelRows { len: 32 }),
        ] {
            let r = bench(
                &format!("fake_quant_e4m3_gam_{pname}_512x512_{label}"),
                &opts,
                || {
                    let fq = fake_quantize_with(
                        black_box(&x),
                        ReprType::E4M3,
                        partition,
                        ScalingAlgo::Gam,
                        &cfg,
                    );
                    black_box(fq.global_err.mean());
                },
            );
            report_throughput(&format!("fake_quant_{pname}_{label}"), &r, elems, "elem");
            if let Some(s) = &mut snap {
                s.record(&r);
                s.record_throughput(&format!("fake_quant_{pname}_{label}"), &r, elems, "elem");
            }
        }
    }
    // Kernel-engine rows: the whole fake-quant pipeline under the
    // scalar oracle vs the LUT/slice kernel layer vs the AVX2 segment
    // QDQ at the default engine+thread configuration (the simd row
    // falls back to the LUT kernel on hosts without AVX2).
    for (label, cfg) in kernel_comparison_rows() {
        let r = bench(&format!("fake_quant_e4m3_gam_block128_512x512_qdq_{label}"), &opts, || {
            let fq = fake_quantize_with(
                black_box(&x),
                ReprType::E4M3,
                Partition::BLOCK128,
                ScalingAlgo::Gam,
                &cfg,
            );
            black_box(fq.global_err.mean());
        });
        report_throughput(&format!("fake_quant_qdq_{label}"), &r, elems, "elem");
        if let Some(s) = &mut snap {
            s.record(&r);
            s.record_throughput(&format!("fake_quant_qdq_{label}"), &r, elems, "elem");
        }
    }
    println!(
        "(parallel rows = {} threads; bit-identical to serial by the par-engine contract)",
        Parallelism::auto().threads
    );
    if let Some(s) = &snap {
        s.write(Parallelism::auto().threads).expect("writing bench snapshot");
    }
}
