//! MoR framework overhead: full recipe application per tensor (the
//! fake-quant + metric + Algorithm-2 walk) across partition strategies,
//! recipes and decision policies — the host-mirror cost model for the
//! paper's "dynamic decisions at runtime" claim.
//!
//! `--json <path>` merges the rows into the shared perf snapshot
//! (`BENCH_9.json` in CI); `--warmup-ms` / `--measure-ms` /
//! `--min-batches` shrink the budget for CI runs.

use mor::mor::policy;
use mor::mor::recipes::{ApplyCtx, Recipe, RecipeKind, SubTensorMode};
use mor::quant::partition::Partition;
use mor::scaling::ScalingAlgo;
use mor::tensor::Tensor;
use mor::util::bench::{bench, report_throughput, BenchOptions, JsonSnapshot};
use mor::util::cli::Args;
use mor::util::par;
use std::hint::black_box;

fn main() {
    let args = Args::from_env();
    let opts = BenchOptions::default().with_args(&args);
    let mut snap = JsonSnapshot::from_args("mor_decision", &args);
    let x = Tensor::normal(&[256, 256], 2.0, 5);
    let elems = (256 * 256) as f64;

    for (label, partition) in [
        ("block128", Partition::BLOCK128),
        ("block64", Partition::BLOCK64),
        ("tensor", Partition::Tensor),
        ("channel", Partition::ChannelRows),
    ] {
        let recipe = Recipe {
            kind: RecipeKind::TensorLevel { threshold: 0.045 },
            partition,
            scaling: ScalingAlgo::Gam,
        };
        let r = bench(&format!("tensor_level_{label}_256x256"), &opts, || {
            let o = recipe.apply(black_box(&x));
            black_box(o);
        });
        report_throughput(&format!("tensor_level_{label}"), &r, elems, "elem");
        if let Some(s) = snap.as_mut() {
            s.record(&r);
            s.record_throughput(&format!("tensor_level_{label}"), &r, elems, "elem");
        }
    }

    for mode in [SubTensorMode::TwoWay, SubTensorMode::ThreeWay] {
        let recipe = Recipe {
            kind: RecipeKind::SubTensor { mode },
            partition: Partition::BLOCK128,
            scaling: ScalingAlgo::Gam,
        };
        let r = bench(&format!("subtensor_{mode:?}_256x256"), &opts, || {
            let o = recipe.apply(black_box(&x));
            black_box(o);
        });
        report_throughput(&format!("subtensor_{mode:?}"), &r, elems, "elem");
        if let Some(s) = snap.as_mut() {
            s.record(&r);
            s.record_throughput(&format!("subtensor_{mode:?}"), &r, elems, "elem");
        }
    }

    // Decision-policy comparison on the heaviest recipe (three-way
    // sub-tensor): what swapping the paper's threshold logic for the
    // relerr-budget or static-assignment policy costs per application.
    // Same tensor, same recipe — only `ApplyCtx::policy` varies.
    let recipe = Recipe {
        kind: RecipeKind::SubTensor { mode: SubTensorMode::ThreeWay },
        partition: Partition::BLOCK128,
        scaling: ScalingAlgo::Gam,
    };
    let cfg = par::global();
    for spec in ["threshold", "metric=0.03", "static=e4m3,e4m3,e5m2"] {
        let pol = policy::parse_policy(Some(spec))
            .expect("bench policy spec parses")
            .expect("non-empty spec");
        let ctx = ApplyCtx::new(&cfg, pol.as_ref());
        let r = bench(&format!("policy_{}_subtensor3_256x256", pol.describe()), &opts, || {
            let o = recipe.apply_ctx(black_box(&x), &ctx);
            black_box(o);
        });
        report_throughput(&format!("policy_{}", pol.describe()), &r, elems, "elem");
        if let Some(s) = snap.as_mut() {
            s.record(&r);
            s.record_throughput(&format!("policy_{}", pol.describe()), &r, elems, "elem");
        }
    }

    // Decision walk alone (metrics precomputed): the pure Algorithm-2
    // overhead, which the paper treats as free.
    let fw = mor::mor::framework::MorFramework::e4m3_e5m2_bf16();
    let metrics: Vec<(f64, f64, bool)> =
        (0..1024).map(|i| (i as f64 * 0.1, i as f64 * 0.11, i % 3 == 0)).collect();
    let r = bench("algorithm2_walk_1024blocks", &opts, || {
        let types = fw.select_all(1024, |t, b| match t {
            mor::formats::ReprType::E4M3 => metrics[b].0 < metrics[b].1,
            mor::formats::ReprType::E5M2 => metrics[b].2,
            _ => false,
        });
        black_box(types);
    });
    report_throughput("algorithm2_walk", &r, 1024.0, "block");
    if let Some(s) = snap.as_mut() {
        s.record(&r);
        s.record_throughput("algorithm2_walk", &r, 1024.0, "block");
    }

    if let Some(s) = snap {
        s.write(par::global().threads).expect("write bench snapshot");
    }
}
