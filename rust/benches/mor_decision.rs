//! MoR framework overhead: full recipe application per tensor (the
//! fake-quant + metric + Algorithm-2 walk) across partition strategies
//! and recipes — the host-mirror cost model for the paper's "dynamic
//! decisions at runtime" claim.

use mor::mor::recipes::{Recipe, RecipeKind, SubTensorMode};
use mor::quant::partition::Partition;
use mor::scaling::ScalingAlgo;
use mor::tensor::Tensor;
use mor::util::bench::{bench, report_throughput, BenchOptions};
use std::hint::black_box;

fn main() {
    let opts = BenchOptions::default();
    let x = Tensor::normal(&[256, 256], 2.0, 5);
    let elems = (256 * 256) as f64;

    for (label, partition) in [
        ("block128", Partition::BLOCK128),
        ("block64", Partition::BLOCK64),
        ("tensor", Partition::Tensor),
        ("channel", Partition::ChannelRows),
    ] {
        let recipe = Recipe {
            kind: RecipeKind::TensorLevel { threshold: 0.045 },
            partition,
            scaling: ScalingAlgo::Gam,
        };
        let r = bench(&format!("tensor_level_{label}_256x256"), &opts, || {
            let o = recipe.apply(black_box(&x));
            black_box(o);
        });
        report_throughput(&format!("tensor_level_{label}"), &r, elems, "elem");
    }

    for mode in [SubTensorMode::TwoWay, SubTensorMode::ThreeWay] {
        let recipe = Recipe {
            kind: RecipeKind::SubTensor { mode },
            partition: Partition::BLOCK128,
            scaling: ScalingAlgo::Gam,
        };
        let r = bench(&format!("subtensor_{mode:?}_256x256"), &opts, || {
            let o = recipe.apply(black_box(&x));
            black_box(o);
        });
        report_throughput(&format!("subtensor_{mode:?}"), &r, elems, "elem");
    }

    // Decision walk alone (metrics precomputed): the pure Algorithm-2
    // overhead, which the paper treats as free.
    let fw = mor::mor::framework::MorFramework::e4m3_e5m2_bf16();
    let metrics: Vec<(f64, f64, bool)> =
        (0..1024).map(|i| (i as f64 * 0.1, i as f64 * 0.11, i % 3 == 0)).collect();
    let r = bench("algorithm2_walk_1024blocks", &opts, || {
        let types = fw.select_all(1024, |t, b| match t {
            mor::formats::ReprType::E4M3 => metrics[b].0 < metrics[b].1,
            mor::formats::ReprType::E5M2 => metrics[b].2,
            _ => false,
        });
        black_box(types);
    });
    report_throughput("algorithm2_walk", &r, 1024.0, "block");
}
